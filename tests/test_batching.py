"""Pipelined data plane: ring slots, batched calls, vectorized MAC.

Covers the PR-3 surface end to end: ring wrap-around and partial drains at
the transport layer, scalar/batch MAC equivalence in framing and kernels,
the gateway batch envelope (per-item typed errors, sequence discipline),
and fault injection mid-batch staying typed and bounded.
"""
import time

import numpy as np
import pytest

from repro.core import TRANSPORTS, ServiceGateway, framing
from repro.core.faultwire import FaultFabric, FaultPlan
from repro.core.gateway import (GW_MAGIC, _BOK, _OK, _ROUTE_BYTES,
                                _batch_route)
from repro.core.transports import (CapacityError, HandlerCrash,
                                   MPKLinkOptTransport, ResponseTimeout,
                                   ServiceCrashed, ShmTransport,
                                   TransportError)
from repro.core.wordcount import make_text, parse_count, wordcount_handler

TIME_BUDGET = 10.0                  # bounded-failure wall-clock ceiling


# ---------------------------------------------------------------------------
# framing + kernels: batched MAC is bit-identical to the scalar path
# ---------------------------------------------------------------------------

def _arrays():
    rng = np.random.default_rng(7)
    out = [rng.integers(0, 256, size=n, dtype=np.int64).astype(np.uint8)
           for n in (1, 511, 512, 513, 4096, 1)]
    out.append(np.arange(12, dtype=np.int32).reshape(3, 4))
    out.append(np.zeros(0, np.uint8))           # empty payload frame
    return out


def test_mac_batch_matches_scalar():
    seed = 0xBEEF1234
    payloads = [framing.pack_payload(a)[0] for a in _arrays()]
    batched = framing.mac_batch(payloads, seed)
    scalar = [framing._mac_np(p, seed) for p in payloads]
    assert batched == scalar


def test_seal_batch_matches_build_frame():
    seed, start = 0x5EED, 41
    arrays = _arrays()
    batched = framing.seal_batch(arrays, seed=seed, start_seq=start)
    scalar = [framing.build_frame(a, seed=seed, seq=start + i)
              for i, a in enumerate(arrays)]
    for b, s in zip(batched, scalar):
        np.testing.assert_array_equal(b, s)
    # explicit (gappy) sequence numbers — the response-seal path
    gappy = framing.seal_batch(arrays[:3], seed=seed, seqs=[3, 9, 12])
    for f, q in zip(gappy, (3, 9, 12)):
        assert int(f[0, 2]) == q


def test_verify_batch_roundtrip_and_partial_failure():
    seed = 0xA11CE
    arrays = _arrays()
    frames = [f.copy() for f in
              framing.seal_batch(arrays, seed=seed, start_seq=0)]
    outs = framing.verify_batch(frames, seed=seed, start_seq=0)
    for o, a in zip(outs, arrays):
        np.testing.assert_array_equal(
            o.reshape(-1).view(np.uint8), a.reshape(-1).view(np.uint8))
    # corrupt one frame: strict raises with the batch index, non-strict
    # returns the FrameError in place and every other frame still verifies
    frames[2][1, 5] ^= np.uint32(1 << 9)
    with pytest.raises(framing.FrameError, match="frame 2"):
        framing.verify_batch(frames, seed=seed, start_seq=0)
    res = framing.verify_batch(frames, seed=seed, start_seq=0, strict=False)
    assert isinstance(res[2], framing.FrameError)
    assert sum(isinstance(r, framing.FrameError) for r in res) == 1


def test_verify_batch_scalar_mac_impl_cross_check():
    """mac_impl forces the per-frame scalar MAC — results must agree with
    the default vectorized pass."""
    seed = 77
    frames = framing.seal_batch(_arrays(), seed=seed, start_seq=5,
                                mac_impl=framing._mac_np)
    a = framing.verify_batch(frames, seed=seed, start_seq=5)
    b = framing.verify_batch(frames, seed=seed, start_seq=5,
                             mac_impl=framing._mac_np)
    for x, y in zip(a, b):
        np.testing.assert_array_equal(np.asarray(x), np.asarray(y))


def test_split_frames_roundtrip_and_desync():
    seed = 3
    frames = framing.seal_batch(_arrays(), seed=seed, start_seq=0)
    flat = np.concatenate(frames, axis=0)
    parts = framing.split_frames(flat)
    assert len(parts) == len(frames)
    for p, f in zip(parts, frames):
        np.testing.assert_array_equal(p, f)
    # corrupting a header length desyncs the walk → typed FrameError
    bad = flat.copy()
    bad[0, 3] = 0xFFFF                  # first frame lies about its size
    with pytest.raises(framing.FrameError):
        framing.split_frames(bad)


def test_kernel_mac_batch_agrees_with_host():
    jax = pytest.importorskip("jax")
    import jax.numpy as jnp
    from repro.kernels.ops import guard_mac_batch
    from repro.kernels.ref import mac_ref

    stack = np.asarray(jax.random.bits(jax.random.PRNGKey(2), (4, 8, 128),
                                       dtype=jnp.uint32))
    tag = 0x77
    host = framing.mac_batch(list(stack), tag)
    pallas = guard_mac_batch(jnp.asarray(stack), jnp.uint32(tag),
                             rows_per_tile=4)
    jnp_twin = guard_mac_batch(jnp.asarray(stack), jnp.uint32(tag),
                               impl="jnp")
    scalar = [int(mac_ref(jnp.asarray(s), jnp.uint32(tag))) for s in stack]
    assert host == [int(x) for x in pallas] == [int(x) for x in jnp_twin] \
        == scalar


# ---------------------------------------------------------------------------
# transport ring: wrap-around, partial drain, sync batching
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("name", sorted(TRANSPORTS))
def test_call_batch_roundtrip_every_transport(name):
    tr = TRANSPORTS[name](wordcount_handler)
    tr.start()
    s = tr.connect("batcher")
    try:
        ns = [1, 40, 400, 7]
        outs = s.call_batch([make_text(n, seed=n) for n in ns])
        assert [parse_count(np.asarray(o)) for o in outs] == ns
    finally:
        tr.close()


@pytest.mark.parametrize("cls", [ShmTransport, MPKLinkOptTransport])
def test_ring_wraparound(cls):
    """More messages than ring slots: tickets wrap the slot array and every
    response still lands on its own ticket."""
    tr = cls(wordcount_handler, ring_slots=4)
    s = tr.connect("wrap")
    try:
        for base in range(0, 12, 3):            # 12 messages through 4 slots
            tickets = [s.submit(make_text(base + i + 1, seed=i))
                       for i in range(3)]
            s.flush()
            got = [parse_count(np.asarray(s.poll(t))) for t in tickets]
            assert got == [base + 1, base + 2, base + 3]
        assert s._tickets == 12
    finally:
        tr.close()


def test_ring_full_is_typed_capacity_error():
    # credit_wait shortens the backpressure window: a serial caller (nobody
    # polling concurrently) must still end in the typed CapacityError
    tr = ShmTransport(wordcount_handler, ring_slots=2, credit_wait=0.05)
    s = tr.connect("full")
    try:
        t0 = s.submit(make_text(1, seed=0))
        t1 = s.submit(make_text(2, seed=0))
        with pytest.raises(CapacityError, match="ring full"):
            s.submit(make_text(3, seed=0))
        s.flush()
        assert parse_count(np.asarray(s.poll(t0))) == 1
        assert parse_count(np.asarray(s.poll(t1))) == 2
        # slot freed — submitting works again
        t2 = s.submit(make_text(3, seed=0))
        assert parse_count(np.asarray(s.poll(t2))) == 3
        # re-polling a redeemed ticket whose SLOT was since reused must
        # fail typed immediately — never a deadline wait that poisons a
        # healthy session
        with pytest.raises(TransportError, match="already redeemed"):
            s.poll(t0)
        t3 = s.submit(make_text(4, seed=0))     # session still healthy
        assert parse_count(np.asarray(s.poll(t3))) == 4
    finally:
        tr.close()


def test_partial_batch_drain():
    """The service drains only published slots: staged-but-unflushed
    messages wait, and polling one ticket doesn't disturb the others."""
    tr = MPKLinkOptTransport(wordcount_handler, ring_slots=8)
    s = tr.connect("partial")
    try:
        first = [s.submit(make_text(n, seed=n)) for n in (5, 6)]
        s.flush()
        assert parse_count(np.asarray(s.poll(first[0]))) == 5
        staged = s.submit(make_text(7, seed=7))     # staged, not flushed
        assert parse_count(np.asarray(s.poll(first[1]))) == 6
        assert parse_count(np.asarray(s.poll(staged))) == 7  # poll flushes
        with pytest.raises(TransportError, match="already redeemed"):
            s.poll(staged)
    finally:
        tr.close()


def test_ring_key_syncs_are_batched():
    """16 messages: lockstep pays 2 syncs each; one call_batch pays ~2
    total — the 'drains them without per-message key-sync round-trips'
    claim, measured."""
    tr = MPKLinkOptTransport(wordcount_handler, ring_slots=16)
    lock = tr.connect("lockstep")
    base = tr.sync_count
    for i in range(16):
        lock.request(make_text(i + 1, seed=i))
    lockstep_syncs = tr.sync_count - base

    batch = tr.connect("batched")
    base = tr.sync_count
    outs = batch.call_batch([make_text(i + 1, seed=i) for i in range(16)])
    batch_syncs = tr.sync_count - base
    tr.close()
    assert [parse_count(np.asarray(o)) for o in outs] == list(range(1, 17))
    assert lockstep_syncs >= 32
    assert batch_syncs <= 2


def test_batched_mac_equals_scalar_on_the_wire():
    """A ring batch (vectorized MAC) and a lockstep exchange (scalar MAC)
    interleave on one session — both sides stay sequence- and
    MAC-consistent, so the two paths are provably the same protocol."""
    tr = MPKLinkOptTransport(wordcount_handler)
    s = tr.connect("mixed")
    try:
        assert parse_count(np.asarray(s.request(make_text(3, seed=0)))) == 3
        outs = s.call_batch([make_text(n, seed=n) for n in (4, 5)])
        assert [parse_count(np.asarray(o)) for o in outs] == [4, 5]
        assert parse_count(np.asarray(s.request(make_text(6, seed=0)))) == 6
        assert s._seq == 4
    finally:
        tr.close()


# ---------------------------------------------------------------------------
# faults mid-batch: typed and bounded
# ---------------------------------------------------------------------------

def test_ring_corrupt_mac_mid_batch_stays_typed():
    """A tampered frame staged into the ring fails ITS poll with FrameError;
    its neighbours drain normally."""
    tr = MPKLinkOptTransport(wordcount_handler, ring_slots=8)
    s = tr.connect("tamper")
    try:
        good0 = s.submit(make_text(10, seed=0))
        frame = framing.build_frame(make_text(11, seed=1), seed=s.seed,
                                    seq=s._seq, mac_impl=tr._mac).copy()
        frame[0, 11] ^= np.uint32(1)            # flip one MAC bit
        bad = s._stage_frame(frame)
        good1 = s.submit(make_text(12, seed=2))
        s.flush()
        assert parse_count(np.asarray(s.poll(good0))) == 10
        with pytest.raises(framing.FrameError):
            s.poll(bad)
        assert parse_count(np.asarray(s.poll(good1))) == 12
    finally:
        tr.close()


@pytest.mark.parametrize("cls", [ShmTransport, MPKLinkOptTransport])
def test_ring_crash_handler_mid_batch_typed_and_bounded(cls):
    """A handler that kills the service thread mid-drain: every poll
    resolves typed well inside the deadline. shm publishes responses per
    slot, so work completed before the crash is still delivered; mpklink
    seals a drain pass's responses under ONE key sync, so a crash loses the
    whole pass — in both cases never an untyped error or a deadline stall."""
    calls = []

    def crashy(req):
        calls.append(1)
        if len(calls) == 2:
            raise HandlerCrash("boom mid-batch")
        return wordcount_handler(req)

    tr = cls(crashy, ring_slots=8, timeout=TIME_BUDGET * 3)
    s = tr.connect("crash")
    t0 = time.monotonic()
    try:
        tickets = [s.submit(make_text(n, seed=n)) for n in (5, 6, 7)]
        s.flush()
        if cls is ShmTransport:         # per-slot publication: first lands
            assert parse_count(np.asarray(s.poll(tickets[0]))) == 5
        else:                           # batch-sealed responses: pass lost
            with pytest.raises(ServiceCrashed):
                s.poll(tickets[0])
        with pytest.raises(ServiceCrashed):
            s.poll(tickets[1])
        with pytest.raises(ServiceCrashed):
            s.poll(tickets[2])
    finally:
        tr.close()
    assert time.monotonic() - t0 < TIME_BUDGET


def test_ring_drop_response_expires_only_its_ticket():
    """An injected wire drop mid-batch: the dropped ticket's bounded poll
    expires (ResponseTimeout → session poisoned), neighbours that were
    polled first completed normally."""
    from repro.core.transports import DropResponse

    def droppy(req):
        n = parse_count(wordcount_handler(req))
        if n == 6:
            raise DropResponse("dropped")
        return wordcount_handler(req)

    tr = MPKLinkOptTransport(droppy, ring_slots=8, timeout=0.4)
    s = tr.connect("drop")
    t0 = time.monotonic()
    try:
        tickets = [s.submit(make_text(n, seed=n)) for n in (5, 6, 7)]
        s.flush()
        assert parse_count(np.asarray(s.poll(tickets[0]))) == 5
        assert parse_count(np.asarray(s.poll(tickets[2]))) == 7
        with pytest.raises(ResponseTimeout):
            s.poll(tickets[1])
        with pytest.raises(TransportError, match="poisoned"):
            s.poll(tickets[2])                  # poisoned session fails loudly
    finally:
        tr.close()
    assert time.monotonic() - t0 < TIME_BUDGET


# ---------------------------------------------------------------------------
# gateway batch envelope
# ---------------------------------------------------------------------------

def _gw(transport="mpklink_opt", **kw):
    gw = ServiceGateway(transport, **kw)
    gw.register_service("wordcount", wordcount_handler)
    return gw.start()


@pytest.mark.parametrize("name", ["mpklink_opt", "uds", "shm"])
def test_gateway_call_batch_roundtrip(name):
    gw = _gw(name)
    try:
        c = gw.connect("batcher")
        ns = [2, 30, 400]
        outs = c.call_batch("wordcount", [make_text(n, seed=n) for n in ns])
        assert [parse_count(o) for o in outs] == ns
        # interleaves with single calls on the same channel sequence
        assert parse_count(c.call("wordcount", make_text(8, seed=0))) == 8
        outs = c.call_batch("wordcount", [make_text(9, seed=0)])
        assert parse_count(outs[0]) == 9
        assert gw.stats["macs_verified"] == 5
        assert c.macs_verified == 5
        assert gw.stats["rejected"] == 0
        c.close()
    finally:
        gw.close()


def test_gateway_batch_handler_errors_are_per_item():
    def picky(req):
        if np.asarray(req).size == 1:
            raise ValueError("bad apple")
        return np.asarray(req)

    gw = ServiceGateway("mpklink_opt")
    gw.register_service("picky", picky, failure_threshold=100)
    gw.start()
    try:
        c = gw.connect("x")
        res = c.call_batch(
            "picky", [np.arange(4, dtype=np.int32), np.zeros(1, np.int32),
                      np.arange(3, dtype=np.int32)], return_exceptions=True)
        assert isinstance(res[1], TransportError)
        assert "bad apple" in str(res[1])
        np.testing.assert_array_equal(
            np.asarray(res[0]).view(np.int32), np.arange(4, dtype=np.int32))
        np.testing.assert_array_equal(
            np.asarray(res[2]).view(np.int32), np.arange(3, dtype=np.int32))
        # without return_exceptions the first per-item error is raised after
        # the batch drained — and the channel sequence stays aligned
        with pytest.raises(TransportError, match="bad apple"):
            c.call_batch("picky", [np.zeros(1, np.int32)])
        out = c.call_batch("picky", [np.arange(2, dtype=np.int32)])
        np.testing.assert_array_equal(
            np.asarray(out[0]).view(np.int32), np.arange(2, dtype=np.int32))
    finally:
        gw.close()


def test_gateway_batch_corrupt_mac_mid_batch():
    """Forged batch envelope with one tampered frame: the gateway answers
    item-by-item — OK, FrameError blob, OK — and the wire count proves only
    the intact frames were MAC-verified."""
    gw = _gw()
    try:
        c = gw.connect("m")
        chan = c.open("wordcount")
        frames = framing.seal_batch(
            [make_text(n, seed=n) for n in (3, 4, 5)],
            seed=chan.seed, start_seq=chan.seq)
        frames[1] = frames[1].copy()
        frames[1][0, 11] ^= np.uint32(1 << 3)
        env = np.concatenate([_batch_route(chan.sid, c.cid, 3)]
                             + [f.reshape(-1).view(np.uint8) for f in frames])
        resp = np.ascontiguousarray(np.asarray(c._session.request(env))) \
            .view(np.uint8).reshape(-1)
        route = resp[:_ROUTE_BYTES].view("<u4")
        assert int(route[0]) == GW_MAGIC and int(route[1]) == _BOK
        statuses, ofs = [], _ROUTE_BYTES
        for _ in range(3):
            ih = resp[ofs: ofs + _ROUTE_BYTES].view("<u4")
            statuses.append(int(ih[1]))
            nb = int(ih[2])
            ofs += _ROUTE_BYTES + nb + ((-nb) % 4)
        assert statuses == [_OK, 1, _OK]
        assert gw.stats["macs_verified"] == 2
        assert gw.stats["rejected"] == 1
        chan.seq += 3                       # our hand-rolled envelope's seqs
        assert parse_count(c.call("wordcount", make_text(6, seed=0))) == 6
    finally:
        gw.close()


def test_gateway_batch_crash_handler_mid_batch_typed_and_bounded():
    """faultwire crash_handler fired while a batch envelope is in flight:
    the client gets ONE typed ServiceCrashed immediately (no deadline
    stall), and a healed client resumes batching."""
    gw = ServiceGateway("mpklink_opt",
                        transport_kwargs={"timeout": TIME_BUDGET * 3})
    gw.register_service("wordcount", wordcount_handler)
    gw.start()
    plan = FaultPlan(seed=99, n_requests=4, rate=0.25,
                     kinds=("crash_handler",))
    [ev] = plan.schedule()
    fabric = FaultFabric(plan).attach(gw)
    t0 = time.monotonic()
    try:
        c = gw.connect("b")
        ns = [3, 4]
        for idx in range(4):
            if idx == ev.index:
                with pytest.raises(ServiceCrashed):
                    c.call_batch("wordcount",
                                 [make_text(n, seed=n) for n in ns])
                c.heal("wordcount")
            else:
                outs = c.call_batch("wordcount",
                                    [make_text(n, seed=n) for n in ns])
                assert [parse_count(o) for o in outs] == ns
        assert [e.kind for e in fabric.fired] == ["crash_handler"]
    finally:
        fabric.detach()
        gw.close()
    assert time.monotonic() - t0 < TIME_BUDGET


def test_gateway_batch_rekeys_after_epoch_bump():
    """A revocation elsewhere on the domain bumps the epoch; a
    still-certified batch client re-keys through the CA transparently —
    the same recovery contract call() has."""
    gw = _gw()
    try:
        a, b = gw.connect("alice"), gw.connect("bob")
        assert parse_count(a.call("wordcount", make_text(3, seed=0))) == 3
        assert parse_count(
            b.call_batch("wordcount", [make_text(4, seed=0)])[0]) == 4
        old_key = b._channels["wordcount"].client_key
        gw.revoke(a, "wordcount")           # epoch bump stales bob's key
        outs = b.call_batch("wordcount",
                            [make_text(6, seed=0), make_text(7, seed=0)])
        assert [parse_count(o) for o in outs] == [6, 7]
        assert b._channels["wordcount"].client_key is not old_key
    finally:
        gw.close()


def test_gateway_unframeable_handler_output_never_desyncs():
    """Response sealing happens after the sequence advance, so it must
    never fail: rank>4 handler output is flattened to bytes (a typed
    answer), and the channel stays aligned for both call paths."""
    gw = ServiceGateway("mpklink_opt")
    gw.register_service("r5", lambda r: np.zeros((2, 2, 2, 2, 2), np.int32))
    gw.register_service("wordcount", wordcount_handler)
    gw.start()
    try:
        c = gw.connect("x")
        out = c.call_batch("r5", [np.arange(3, dtype=np.int32)])[0]
        assert out.dtype == np.uint8 and out.size == 32 * 4
        c.call("r5", np.arange(3, dtype=np.int32))
        assert parse_count(c.call("wordcount", make_text(5, seed=0))) == 5
    finally:
        gw.close()


def test_gateway_batch_whole_envelope_rejections_are_typed():
    gw = _gw()
    try:
        from repro.core.domains import AccessViolation
        c = gw.connect("n")
        chan = c.open("wordcount")
        # unknown service id → AccessViolation, sequence NOT consumed
        frames = framing.seal_batch([make_text(2, seed=0)],
                                    seed=chan.seed, start_seq=chan.seq)
        env = np.concatenate([_batch_route(0x7FFF, c.cid, 1)]
                             + [f.reshape(-1).view(np.uint8) for f in frames])
        resp = np.ascontiguousarray(np.asarray(c._session.request(env))) \
            .view(np.uint8).reshape(-1)
        route = resp[:_ROUTE_BYTES].view("<u4")
        assert int(route[1]) == 1
        with pytest.raises(AccessViolation):
            from repro.core.transports import _raise_remote
            _raise_remote(resp[_ROUTE_BYTES:
                               _ROUTE_BYTES + int(route[3])].tobytes())
        # channel still aligned: the real batch path works
        outs = c.call_batch("wordcount", [make_text(5, seed=0)])
        assert parse_count(outs[0]) == 5
    finally:
        gw.close()
