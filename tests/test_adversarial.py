"""Active-attacker suite against the self-healing gateway.

Every attack must be rejected with a typed error AND leave zero state
corruption: after each one we re-assert the registry/CA invariants and that
legitimate clients still get correct answers. Attacks are hand-built wire
envelopes (no fault fabric) so each is exactly the adversary's move."""
import numpy as np
import pytest

from repro.core import ServiceGateway, framing
from repro.core import signature as sig
from repro.core.ca import enroll
from repro.core.domains import RW, AccessViolation
from repro.core.gateway import GW_MAGIC, _ROUTE_BYTES, _route
from repro.core.transports import _raise_remote
from repro.core.wordcount import make_text, parse_count, wordcount_handler


def _reverse(req):
    return np.ascontiguousarray(np.asarray(req)[::-1])


def _gateway(transport="mpklink_opt", **kw):
    gw = ServiceGateway(transport, **kw)
    gw.register_service("wordcount", wordcount_handler)
    gw.register_service("reverse", _reverse)
    return gw.start()


def assert_invariants(gw):
    """Registry/CA invariants that must survive every attack:
    live channel keys are issued + epoch-current, service keys pass their
    own PKRU check, the domain table is within the hardware budget, and
    certificate records verify."""
    reg = gw.registry
    for (cid, sid), ch in list(gw._channels.items()):
        dom = ch.client_key.domain
        assert dom.did in reg._domains, "channel on a freed domain"
        if ch.client_key.epoch == reg.epoch(dom):
            assert ch.client_key.nonce in reg._issued[dom.did], \
                "epoch-current channel holds an unissued/revoked key"
        else:
            # lazily re-keyed channel: MUST fail the PKRU check loudly the
            # moment it is used — stale keys never pass silently
            with pytest.raises(AccessViolation):
                reg.check(ch.client_key, RW)
    for svc in gw._services.values():
        reg.check(svc.server_key, RW)          # raises on any corruption
        assert svc.server_key.epoch == reg.epoch(svc.domain)
    assert len(reg._domains) <= reg._max
    for rec in gw.ca._services.values():
        if rec.verified:
            assert gw.ca.verify_cert(rec), f"corrupt cert for {rec.name}"


def _send_raw(client, sid, cid, frame):
    env = np.concatenate([_route(sid, cid, 0),
                          frame.reshape(-1).view(np.uint8)])
    resp = np.ascontiguousarray(np.asarray(client._session.request(env))) \
        .view(np.uint8).reshape(-1)
    route = resp[:_ROUTE_BYTES].view("<u4")
    assert int(route[0]) == GW_MAGIC
    return int(route[1]), resp[_ROUTE_BYTES:]


def _expect_reject(client, sid, cid, frame, exc_types):
    status, body = _send_raw(client, sid, cid, frame)
    assert status == 1, "gateway ACCEPTED an attack envelope"
    with pytest.raises(exc_types):
        _raise_remote(body[: 512].tobytes())


# ---------------------------------------------------------------------------
# 1. replayed frames under an old epoch
# ---------------------------------------------------------------------------

def test_old_epoch_replay_rejected():
    gw = _gateway()
    try:
        alice, bob = gw.connect("alice"), gw.connect("bob")
        assert parse_count(alice.call("wordcount", make_text(7, seed=0))) == 7
        assert parse_count(bob.call("wordcount", make_text(8, seed=0))) == 8
        a_chan = alice._channels["wordcount"]
        b_chan = bob._channels["wordcount"]
        # capture a frame exactly as alice would send her NEXT request,
        # and bob's stale-seed image, BEFORE the epoch bump
        a_replay = framing.build_frame(make_text(7, seed=0),
                                       seed=a_chan.seed, seq=a_chan.seq)
        b_stale = framing.build_frame(make_text(8, seed=0),
                                      seed=b_chan.seed, seq=b_chan.seq)
        gw.revoke(alice, "wordcount")          # epoch bump on the domain

        # alice's captured frame: her channel is gone → no key for cid
        _expect_reject(alice, a_chan.sid, alice.cid, a_replay,
                       AccessViolation)
        # bob still holds a channel object, but its key is one epoch old:
        # the PKRU staging check rejects before the handler ever runs
        _expect_reject(bob, b_chan.sid, bob.cid, b_stale, AccessViolation)
        assert_invariants(gw)

        # zero corruption: bob transparently re-keys and keeps working
        assert parse_count(bob.call("wordcount", make_text(9, seed=1))) == 9
        # ...and an in-sequence replay of bob's OWN earlier frame under the
        # NEW epoch still fails (sequence window moved on)
        nb = bob._channels["wordcount"]
        replay2 = framing.build_frame(make_text(9, seed=1), seed=nb.seed,
                                      seq=nb.seq - 1)
        _expect_reject(bob, nb.sid, bob.cid, replay2, framing.FrameError)
        assert_invariants(gw)
        assert parse_count(bob.call("wordcount", make_text(5, seed=2))) == 5
    finally:
        gw.close()


# ---------------------------------------------------------------------------
# 2. cross-service seed reuse
# ---------------------------------------------------------------------------

def test_cross_service_seed_reuse_rejected():
    gw = _gateway()
    try:
        eve = gw.connect("eve")
        chan_wc = eve.open("wordcount")
        chan_rv = eve.open("reverse")
        payload = np.arange(16, dtype=np.int32)

        # a frame MAC-seeded for wordcount, addressed to reverse (and vice
        # versa): the per-service domain seed must reject it at the guard
        f_wc = framing.build_frame(payload, seed=chan_wc.seed,
                                   seq=chan_rv.seq)
        _expect_reject(eve, chan_rv.sid, eve.cid, f_wc, framing.FrameError)
        f_rv = framing.build_frame(payload, seed=chan_rv.seed,
                                   seq=chan_wc.seq)
        _expect_reject(eve, chan_wc.sid, eve.cid, f_rv, framing.FrameError)
        assert_invariants(gw)

        # neither service's sequence window moved: in-order calls still work
        np.testing.assert_array_equal(
            np.asarray(eve.call("reverse", payload)), payload[::-1])
        assert parse_count(eve.call("wordcount", make_text(6, seed=3))) == 6
        assert gw.stats["rejected"] >= 2
    finally:
        gw.close()


# ---------------------------------------------------------------------------
# 3. revoked client re-registering under a new name with a stolen key
# ---------------------------------------------------------------------------

def test_revoked_identity_cannot_alias_with_stolen_key():
    gw = _gateway()
    try:
        mallory = gw.connect("mallory")
        assert parse_count(mallory.call("wordcount", make_text(4, seed=0))) == 4
        gw.ca.revoke_service("mallory")

        # same name: refused (ban survives reconnects)
        with pytest.raises(AccessViolation, match="revoked"):
            gw.connect("mallory")

        # new name, STOLEN key: mallory's key pair signs a registration for
        # "totally-new-client" — the CA must refuse the alias, revoked keys
        # don't get fresh identities
        stolen = sig.KeyPair.generate("mallory")
        proof = sig.sign(stolen.private,
                         f"register:totally-new-client:{stolen.public}".encode())
        with pytest.raises(AccessViolation, match="bound to identity"):
            gw.ca.register("totally-new-client", stolen.public, proof)
        # and the enroll() convenience path for an honest new client still
        # works (fresh key pair → fresh identity)
        enroll(gw.ca, "honest-newcomer")
        assert_invariants(gw)

        # mallory's existing channel is dead too: her next call re-keys via
        # the CA, which refuses the revoked certificate
        gw.revoke(mallory, "wordcount")
        with pytest.raises(AccessViolation):
            mallory.call("wordcount", make_text(4, seed=1))
        assert_invariants(gw)
    finally:
        gw.close()


# ---------------------------------------------------------------------------
# 4. open/close spam: channel/key exhaustion
# ---------------------------------------------------------------------------

def test_open_close_spam_cannot_exhaust_channels():
    gw = _gateway(max_keys=24)
    reg = gw.registry
    try:
        legit = gw.connect("legit")
        assert parse_count(legit.call("wordcount", make_text(5, seed=0))) == 5
        domains_before = len(reg._domains)

        # (a) channel-level spam: re-keying the same service 100× must not
        # grow the issued-key table (replaced grants are retired)
        spammer = gw.connect("spammer")
        spammer.open("wordcount")
        svc_dom = gw._services["wordcount"].domain
        issued_before = len(reg._issued[svc_dom.did])
        for _ in range(100):
            spammer.reopen("wordcount")
        assert len(reg._issued[svc_dom.did]) == issued_before
        assert parse_count(spammer.call("wordcount", make_text(6, seed=1))) == 6
        spammer.close()

        # (b) client-level spam: connect/close 50× on a 24-key table —
        # freed link domains must be recycled like pkey_free/pkey_alloc
        for i in range(50):
            c = gw.connect(f"churn-{i}")
            c.open("wordcount")
            assert parse_count(c.call("wordcount", make_text(3, seed=i))) == 3
            c.close()
            assert len(reg._domains) <= reg._max
        assert len(reg._domains) == domains_before + 0 \
            or len(reg._domains) <= domains_before + 1
        assert_invariants(gw)

        # the table still has room for an honest newcomer afterwards
        fresh = gw.connect("fresh-after-spam")
        assert parse_count(fresh.call("wordcount", make_text(11, seed=2))) == 11
        assert_invariants(gw)
    finally:
        gw.close()


# ---------------------------------------------------------------------------
# 5. dedup window cannot be used to double-execute or cross wires
# ---------------------------------------------------------------------------

def test_token_replay_cannot_rewind_the_sequence_window():
    """Replaying a captured envelope WITH its original idempotency token is
    answered from the dedup window (the attacker learns nothing the client
    didn't already receive) but must NOT rewind server_seq — subsequent
    in-order traffic keeps flowing (no one-packet replay DoS)."""
    gw = _gateway()
    try:
        victim = gw.connect("victim")
        chan = victim.open("wordcount")
        # capture request 0's exact envelope (seq 0, token 1) off the wire
        token = 1
        frame0 = framing.build_frame(make_text(7, seed=0), seed=chan.seed,
                                     seq=0)
        env0 = np.concatenate([_route(chan.sid, victim.cid, token),
                               frame0.reshape(-1).view(np.uint8)])
        for i in range(4):              # requests 0..3 complete normally
            assert parse_count(victim.call("wordcount",
                                           make_text(7, seed=0))) == 7
        assert gw._channels[(victim.cid, chan.sid)].server_seq == 4

        # replay the captured envelope: dedup answers it...
        resp = np.ascontiguousarray(
            np.asarray(victim._session.request(env0))) \
            .view(np.uint8).reshape(-1)
        assert int(resp[:_ROUTE_BYTES].view("<u4")[1]) == 0   # served
        assert gw.stats["deduped"] == 1
        # ...but the window did NOT rewind, and legit traffic continues
        assert gw._channels[(victim.cid, chan.sid)].server_seq == 4
        assert parse_count(victim.call("wordcount", make_text(5, seed=1))) == 5
        assert_invariants(gw)
    finally:
        gw.close()


def test_idempotency_tokens_are_client_scoped():
    """A token only dedups within (client id, token): two clients using the
    same token value never see each other's cached responses."""
    gw = _gateway()
    try:
        a, b = gw.connect("a"), gw.connect("b")
        ra = parse_count(a.call("wordcount", make_text(10, seed=0)))
        rb = parse_count(b.call("wordcount", make_text(20, seed=0)))
        assert (ra, rb) == (10, 20)
        svc = gw._services["wordcount"]
        assert {a.cid, b.cid} <= set(svc.done)  # one bucket per client id
        # both clients used token 1 for their first call — the buckets keep
        # them apart, and each client only ever sees its own cached answer
        assert 1 in svc.done[a.cid] and 1 in svc.done[b.cid]
        assert parse_count(svc.done[a.cid][1]) == 10
        assert parse_count(svc.done[b.cid][1]) == 20
        # one client's flood can never evict another client's pending token
        from repro.core import gateway as gwmod
        for i in range(gwmod._DONE_TOKENS * 3):
            b.call("wordcount", make_text(2, seed=i))
        assert 1 in svc.done[a.cid]            # a's window untouched
        assert len(svc.done[b.cid]) == gwmod._DONE_TOKENS
        assert_invariants(gw)
    finally:
        gw.close()
