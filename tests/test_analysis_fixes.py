"""Regression tests for the defects mpklint's first report surfaced.

Each fix pairs with the rule that found it: the counters stay exact
under the exact interleavings that used to drop updates (MPK001), the
dry-run timings stay on the monotonic clock (MPK103), and the gateway's
restart path does its service lookup under the registration lock.
"""
import threading
from pathlib import Path

import numpy as np

from repro.analysis import analyze_paths
from repro.core.gateway import ServiceGateway, _Shard
from repro.core.transports import MPKLinkTransport

ROOT = Path(__file__).resolve().parent.parent


def test_shard_executed_counter_exact_under_close_race():
    """_Shard.executed was bumped unguarded from the shard thread AND
    from callers racing close() (the inline fallback) — MPK001.  With the
    lock, every executed item is counted exactly once."""
    shard = _Shard(0)
    per_thread, n_threads = 200, 4
    handles, hlock = [], threading.Lock()

    def feed():
        for _ in range(per_thread):
            h = shard.submit(lambda: None)
            with hlock:
                handles.append(h)

    threads = [threading.Thread(target=feed) for _ in range(n_threads)]
    for t in threads:
        t.start()
    shard.close()                     # mid-stream: forces inline execution
    for t in threads:
        t.join()
    for _, done in handles:
        assert done.wait(10)
    assert shard.executed == per_thread * n_threads


def test_mpklink_session_sync_count_exact_under_concurrency():
    """MPKLinkSession.sync_count was bumped unguarded from the client
    thread (request/flush) and the service thread (response/drain) —
    MPK001.  The locked helper must not drop a single increment."""
    tr = MPKLinkTransport(handler=lambda a: a)
    try:
        sess = tr._default
        before_t = tr.sync_count
        per_thread, n_threads = 500, 8

        def bump():
            for _ in range(per_thread):
                sess._bump_sync()

        threads = [threading.Thread(target=bump) for _ in range(n_threads)]
        for t in threads:
            t.start()
        for t in threads:
            t.join()
        total = per_thread * n_threads
        assert sess.sync_count == total
        assert tr.sync_count - before_t == total
    finally:
        tr.close()


def test_mpklink_sync_accounting_still_matches_traffic():
    """The refactor is pure accounting: session- and transport-level
    sync counters still move together, by the documented small per-
    exchange cost (cf. test_mpklink_sync_scaling's ``small <= 3``)."""
    tr = MPKLinkTransport(handler=lambda a: a)
    tr.start()
    try:
        payload = np.arange(64, dtype=np.uint8)
        out = tr.request(payload)
        assert np.asarray(out).view(np.uint8).tolist() == payload.tolist()
        assert 1 <= tr.sync_count <= 3
        assert tr._default.sync_count == tr.sync_count
    finally:
        tr.close()


def test_dryrun_measures_on_monotonic_clock():
    """launch/dryrun.py computed t_lower/t_compile from time.time() —
    MPK103.  The analyzer holds the file clean now."""
    report = analyze_paths(
        [ROOT / "src" / "repro" / "launch" / "dryrun.py"])
    assert [f for f in report.new if f.rule == "MPK103"] == []


def test_restart_service_looks_up_under_glock():
    """restart_service read self._services before taking _glock, so a
    concurrent (re-)register could hand it a stale _Service.  Functional
    check: restart under concurrent registration keeps working and the
    restarted service serves from its fresh handler."""
    gw = ServiceGateway("mpklink_opt")
    try:
        gw.register_service("svc", lambda a: np.asarray(a) * 2,
                            factory=lambda: (lambda a: np.asarray(a) * 3))
        gw.start()
        client = gw.connect("c1")
        assert client.call("svc", np.array([2], np.int32)).tolist() == [4]

        def churn():
            for i in range(5):
                gw.register_service(f"extra{i}", lambda a: a)

        t = threading.Thread(target=churn)
        t.start()
        gw.restart_service("svc")
        t.join()
        # factory swapped the handler; still-certified clients re-key
        assert client.call("svc", np.array([2], np.int32)).tolist() == [6]
    finally:
        gw.close()
