"""FleetSupervisor (docs/protocol.md §9): health probing, EWMA outlier
ejection, and pure-planner actuation so capacity converges back to the
target under continuous kill -9.

Planner tests are pure and tier-1; the supervisor-over-in-proc-fleet
tests are tier-1 too (deaths injected via ``_mark_dead``); everything
that forks and kill -9s real replica children is marked ``proc``."""
import os
import signal
import time

import numpy as np
import pytest

from repro.core.gateway import (REPLICA_ACTIVE, REPLICA_DEAD,
                                FleetSupervisor, ServiceGateway)
from repro.runtime.elastic import plan_fleet_scaling, plan_outlier_ejection

_PROC_KW = {"ring_slots": 2, "timeout": 30.0}


def _tagged(i):
    def handler(req):
        return np.concatenate([np.asarray(req, np.uint8),
                               np.array([i], np.uint8)])
    return handler


def _tag(out):
    return int(np.asarray(out)[-1])


def _snap(rid, state="active", ewma=5.0, served=100, inflight=0):
    return {"rid": rid, "state": state, "ewma_ms": ewma,
            "served": served, "inflight": inflight}


# ---------------------------------------------------------------------------
# plan_outlier_ejection: pure policy, guard rails
# ---------------------------------------------------------------------------

def test_ejection_flags_the_slow_replica():
    snap = [_snap(0), _snap(1), _snap(2), _snap(3, ewma=40.0)]
    assert plan_outlier_ejection(snap, factor=4.0) == [("eject", 3)]


def test_ejection_peer_median_excludes_self():
    """One giant outlier cannot drag the median up past itself: with
    peers at 5ms the 500ms replica is ejected even though the median OF
    ALL FOUR would include its own value."""
    snap = [_snap(0), _snap(1), _snap(2), _snap(3, ewma=500.0)]
    assert plan_outlier_ejection(snap) == [("eject", 3)]


def test_ejection_needs_min_peers():
    """Two replicas are not a population — neither can be an outlier of
    the other."""
    snap = [_snap(0), _snap(1, ewma=500.0)]
    assert plan_outlier_ejection(snap, min_peers=3) == []


def test_ejection_spares_warming_replicas():
    """A replica below min_served keeps its EWMA grace period: warmup
    noise (cold caches, lazy fork) must not read as pathology."""
    snap = [_snap(0), _snap(1), _snap(2),
            _snap(3, ewma=500.0, served=5)]
    assert plan_outlier_ejection(snap, min_served=32) == []


def test_ejection_ignores_non_active_and_unobserved():
    snap = [_snap(0), _snap(1), _snap(2, ewma=None),
            _snap(3, state="dead", ewma=900.0),
            _snap(4, state="draining", ewma=900.0)]
    assert plan_outlier_ejection(snap) == []


def test_ejection_orders_by_rid():
    snap = [_snap(5, ewma=90.0), _snap(0), _snap(1), _snap(2),
            _snap(3, ewma=80.0)]
    assert plan_outlier_ejection(snap) == [("eject", 3), ("eject", 5)]


# ---------------------------------------------------------------------------
# supervisor over an in-process fleet (tier-1)
# ---------------------------------------------------------------------------

def _inproc_fleet(n=3):
    gw = ServiceGateway("mpklink_opt")
    for i in range(n):
        gw.register_replica("echo", _tagged(i), transport="mpklink_opt")
    return gw.start()


def test_supervisor_steady_state_is_a_no_op():
    """A healthy fleet at target: probes come back alive, every sweep's
    plan is empty, nothing is respawned, and the trace replays."""
    gw = _inproc_fleet(3)
    sup = FleetSupervisor(gw, "echo", target=3, record=True)
    try:
        for _ in range(3):
            assert sup.sweep() == []
        assert sup.stats["sweeps"] == 3
        assert sup.stats["probes"] == 9
        assert sup.stats["respawns"] == sup.stats["deaths_detected"] == 0
        assert all(v == "alive" for _, probes, _, _ in sup.trace
                   for _, v in probes)
        sup.replay()
    finally:
        gw.close()


def test_supervisor_resurrects_a_dead_replica():
    """A DEAD replica is released (one re-key) and a fresh one joins from
    the fleet's spawn spec — capacity returns to target in one sweep and
    traffic lands on the resurrected set."""
    gw = _inproc_fleet(3)
    fleet = gw.fleet("echo")
    sup = FleetSupervisor(gw, "echo", target=3, record=True)
    try:
        cli = gw.connect("c0")
        for k in range(12):
            cli.call("echo", np.arange(4, dtype=np.uint8))
        victim = fleet._replicas[1]
        fleet._mark_dead(victim)
        plan = sup.sweep()
        assert ("release", 1) in plan and ("join", 1) in plan
        assert sup.stats["releases"] == 1 and sup.stats["respawns"] == 1
        active = [r for r in fleet.snapshot() if r["state"] == "active"]
        assert len(active) == 3
        assert victim.state not in (REPLICA_ACTIVE, REPLICA_DEAD)
        # the next sweep sees a converged fleet: the corpse was released
        # exactly once (no re-key storm)
        assert sup.sweep() == []
        assert sup.stats["releases"] == 1
        # respawns come from the fleet's stored spawn spec (the LAST
        # add()'s handler — tag 2 here); the corpse's tag can never
        # reappear and every post-heal call still lands correctly
        seen = set()
        for _ in range(30):
            out = cli.call("echo", np.arange(4, dtype=np.uint8))
            assert np.asarray(out)[:4].tolist() == [0, 1, 2, 3]
            seen.add(_tag(out))
        assert 1 not in seen
        sup.replay()
        cli.close()
    finally:
        gw.close()


def test_supervisor_drains_surplus_to_target():
    gw = _inproc_fleet(4)
    fleet = gw.fleet("echo")
    sup = FleetSupervisor(gw, "echo", target=2)
    try:
        plan = sup.sweep()
        assert sum(1 for op, _ in plan if op == "drain") == 2
        # drains actuate asynchronously via the re-drain set; one more
        # sweep quiesces them (nothing is in flight)
        sup.sweep()
        active = [r for r in fleet.snapshot() if r["state"] == "active"]
        assert len(active) == 2
        assert sup.stats["drains"] == 2
    finally:
        gw.close()


def test_supervisor_ejects_latency_outlier():
    """A wedged-but-alive replica (EWMA far past the peer median) is
    drained and replaced: the probe can't catch it, the ejection policy
    does."""
    gw = _inproc_fleet(4)
    fleet = gw.fleet("echo")
    sup = FleetSupervisor(gw, "echo", target=4, eject_factor=4.0)
    try:
        for rep in fleet._replicas.values():
            rep.served = 100
            rep.ewma_ms = 5.0
        fleet._replicas[2].ewma_ms = 500.0
        sup.sweep()
        assert sup.stats["ejections"] == 1
        sup.sweep()                     # re-drain + converge
        snap = fleet.snapshot()
        active = [r for r in snap if r["state"] == "active"]
        assert len(active) == 4
        assert all(r["rid"] != 2 for r in active)
        assert sup.stats["respawns"] >= 1
    finally:
        gw.close()


def test_supervisor_lifecycle_guards():
    gw = _inproc_fleet(1)
    try:
        with pytest.raises(ValueError):
            FleetSupervisor(gw, "echo", target=0)
        sup = FleetSupervisor(gw, "echo", target=1,
                              interval=0.05).start()
        with pytest.raises(RuntimeError):
            sup.start()
        time.sleep(0.3)
        sup.stop()
        assert sup.stats["sweeps"] >= 1
    finally:
        gw.close()


def test_supervisor_replay_detects_divergence():
    """A tampered trace fails replay loudly — the planner really is the
    single source of the actuation decisions."""
    gw = _inproc_fleet(2)
    sup = FleetSupervisor(gw, "echo", target=2, record=True)
    try:
        sup.sweep()
        no, probes, snap, _plan = sup.trace[0]
        sup.trace[0] = (no, probes, snap, (("join", 5),))
        with pytest.raises(AssertionError):
            sup.replay()
    finally:
        gw.close()


# ---------------------------------------------------------------------------
# proc: real forked replicas, real kill -9 (CI fleet job)
# ---------------------------------------------------------------------------

def _proc_fleet(n=3):
    gw = ServiceGateway("mpklink_opt")
    for i in range(n):
        gw.register_replica("echo", _tagged(i), transport_kwargs=_PROC_KW)
    return gw.start()


def _warm(cli, fleet, n):
    """Drive enough traffic that every replica has forked its child
    (procwire forks lazily on first request)."""
    for _ in range(12 * n):
        cli.call("echo", np.arange(4, dtype=np.uint8))
        if all(r.session._proc is not None
               for r in fleet._replicas.values()
               if r.state == REPLICA_ACTIVE):
            return
    raise AssertionError("fleet never warmed")


def _wait_healed(sup, fleet, target, min_respawns, timeout=30.0):
    """Wait until the supervisor has actually detected + replaced the
    corpse (a freshly killed child still snapshots as 'active' until a
    probe or routed request notices)."""
    deadline = time.monotonic() + timeout
    while time.monotonic() < deadline:
        active = [r for r in fleet.snapshot() if r["state"] == "active"]
        if (sup.stats["respawns"] >= min_respawns
                and len(active) == target):
            return active
        time.sleep(0.05)
    raise AssertionError(
        f"never healed to {target} with >= {min_respawns} respawns: "
        f"{sup.stats} {fleet.snapshot()}")


@pytest.mark.proc
def test_supervisor_converges_under_continuous_kill9():
    """Two rounds of kill -9 against live proc replicas: the probe loop
    detects each death, releases the corpse (one re-key each), respawns
    fresh proc-backed capacity, and traffic stays correct after every
    heal. The recorded trace replays exactly."""
    gw = _proc_fleet(3)
    fleet = gw.fleet("echo")
    sup = FleetSupervisor(gw, "echo", target=3, interval=0.05,
                          probe_timeout=2.0, record=True)
    try:
        cli = gw.connect("c0", retries=3)
        _warm(cli, fleet, 3)
        sup.start()
        for round_no in range(2):
            victims = [r for r in fleet._replicas.values()
                       if r.state == REPLICA_ACTIVE
                       and r.session._proc is not None]
            os.kill(victims[0].session._proc.pid, signal.SIGKILL)
            _wait_healed(sup, fleet, 3, round_no + 1)
            _warm(cli, fleet, 3)        # fresh replicas fork lazily too
            for k in range(10):
                out = cli.call("echo", np.arange(4, dtype=np.uint8))
                assert np.asarray(out)[:4].tolist() == [0, 1, 2, 3]
        sup.stop()
        assert sup.stats["deaths_detected"] >= 2
        assert sup.stats["respawns"] >= 2
        assert sup.stats["releases"] >= 2
        sup.replay()
        cli.close()
    finally:
        sup.stop()
        gw.close()


@pytest.mark.proc
def test_supervisor_probe_detects_silent_death():
    """A kill -9 victim with NO traffic against it is still detected:
    the probe RPC itself proves the link dead (the router alone would
    only learn at the next routed request)."""
    gw = _proc_fleet(2)
    fleet = gw.fleet("echo")
    sup = FleetSupervisor(gw, "echo", target=2, interval=0.05,
                          probe_timeout=2.0)
    try:
        cli = gw.connect("c0", retries=3)
        _warm(cli, fleet, 2)
        victim = next(r for r in fleet._replicas.values()
                      if r.session._proc is not None)
        os.kill(victim.session._proc.pid, signal.SIGKILL)
        # no traffic at all — only the supervisor's probes run
        sup.start()
        _wait_healed(sup, fleet, 2, 1)
        sup.stop()
        assert sup.stats["deaths_detected"] >= 1
        assert sup.stats["respawns"] >= 1
        cli.close()
    finally:
        sup.stop()
        gw.close()
