"""Replica fleets (docs/protocol.md §8): seeded power-of-two routing,
cohort-aware admission, drain/join under live traffic with one epoch
re-key per membership change, and the chaos kill -9 matrix over real
forked replica children.

Router and scaling-policy tests are pure and tier-1; everything that
forks replica processes is marked ``proc`` (CI runs those in the fleet
job with a flake-detector repeat pass)."""
import os
import signal
import threading
import time

import numpy as np
import pytest

from repro.core.gateway import (FLEET_CHOICES, REPLICA_ACTIVE,
                                REPLICA_DEAD, REPLICA_DRAINING,
                                REPLICA_QUIESCED, ReplicaRouter,
                                ServiceGateway, simulate_assignments)
from repro.core.transports import (ResponseTimeout, ServiceCrashed,
                                   ServiceUnavailable)
from repro.core.wordcount import make_text, parse_count, wordcount_handler
from repro.runtime.elastic import plan_fleet_scaling

_PROC_KW = {"ring_slots": 2, "timeout": 30.0}


def _tagged(i):
    """Replica handler that appends its replica index to the payload —
    the child-side identity that proves where a request actually ran."""
    def handler(req):
        return np.concatenate([np.asarray(req, np.uint8),
                               np.array([i], np.uint8)])
    return handler


def _tag(out):
    return int(np.asarray(out)[-1])


# ---------------------------------------------------------------------------
# router: power-of-two choices, determinism, replay
# ---------------------------------------------------------------------------

def test_router_skew_bounded():
    """Power-of-two + least-loaded keeps per-replica assignment counts
    near-uniform at full load: no replica gets starved or doubled."""
    n, total = 4, 2000
    picks = simulate_assignments(0xBEEF, [i * 1.0 for i in range(total)],
                                 n, 4.0)
    counts = [picks.count(rid) for rid in range(n)]
    mean = total / n
    assert min(counts) > 0.7 * mean, counts
    assert max(counts) < 1.3 * mean, counts


def test_router_skew_beats_single_choice():
    """The '2' in power-of-two is load-bearing: with choices=1 (pure
    random) the max/min spread is measurably worse than with choices=2 on
    the identical arrival trace."""
    arrivals = [i * 1.0 for i in range(2000)]

    def spread(choices):
        picks = simulate_assignments(7, arrivals, 4, 4.0, choices=choices)
        counts = [picks.count(r) for r in range(4)]
        return max(counts) - min(counts)

    assert spread(2) < spread(1), (spread(2), spread(1))


@pytest.mark.parametrize("seed", [0, 1, 7, 0xDEADBEEF])
def test_router_determinism_property(seed):
    """Identical (seed, arrival trace) → identical replica assignment
    sequence — the FaultPlan property that makes a fleet imbalance
    reproduce from a one-line seed."""
    rng = np.random.default_rng(seed)
    arrivals = np.cumsum(rng.exponential(1.0, size=300)).tolist()
    svc = rng.uniform(0.5, 6.0, size=300).tolist()
    a = simulate_assignments(seed, arrivals, 3, svc)
    b = simulate_assignments(seed, arrivals, 3, svc)
    assert a == b
    # a different seed almost surely routes differently on a 300-long trace
    assert a != simulate_assignments(seed + 1, arrivals, 3, svc)


def test_router_trace_replay():
    """A recorded decision trace replays bit-for-bit from a fresh router
    with the same seed; a tampered pick is caught loudly."""
    r = ReplicaRouter(0x5EED, record=True)
    rng = np.random.default_rng(3)
    for _ in range(200):
        loads = [(rid, int(rng.integers(0, 5)), float(rng.uniform(0, 4)))
                 for rid in range(5)]
        r.pick(loads)
    assert r.replay(r.trace) == [t[2] for t in r.trace]
    bad = list(r.trace)
    loads, cands, picked = bad[57]
    other = next(rid for rid, _, _ in loads if rid != picked)
    bad[57] = (loads, cands, other)
    with pytest.raises(AssertionError, match="decision 57"):
        r.replay(bad)


def test_router_candidates_distinct_and_least_loaded():
    r = ReplicaRouter(1, record=True)
    for _ in range(100):
        # rid 2 is always strictly least-loaded: whenever it is drawn it
        # must win; candidates must always be distinct
        r.pick([(0, 5, 9.0), (1, 5, 9.0), (2, 0, 0.1), (3, 5, 9.0)])
    for loads, cands, picked in r.trace:
        assert len(cands) == len(set(cands)) == FLEET_CHOICES
        if 2 in cands:
            assert picked == 2
    assert r.picks == 100 and sum(r.assigned.values()) == 100


def test_router_single_replica_and_empty():
    r = ReplicaRouter(0)
    assert r.pick([(9, 3, 1.0)]) == 9
    with pytest.raises(ServiceUnavailable):
        r.pick([])


def test_simulate_service_time_vector_validation():
    with pytest.raises(ValueError):
        simulate_assignments(0, [0.0, 1.0, 2.0], 2, [1.0, 2.0])


# ---------------------------------------------------------------------------
# elastic scaling policy (pure decision)
# ---------------------------------------------------------------------------

def _snap(rid, state, inflight=0, ewma=1.0):
    return {"rid": rid, "state": state, "inflight": inflight,
            "ewma_ms": ewma, "served": 0, "crashes": 0}


def test_plan_fleet_scaling_release_join_drain():
    snap = [_snap(0, "active", inflight=2), _snap(1, "dead"),
            _snap(2, "active", inflight=0, ewma=None)]
    assert plan_fleet_scaling(snap, 4) == [("release", 1), ("join", 2)]
    # surplus: drains the least-loaded active (rid 2: inflight 0)
    assert plan_fleet_scaling(snap, 1) == [("release", 1), ("drain", 2)]
    assert plan_fleet_scaling(snap, 2) == [("release", 1)]
    assert plan_fleet_scaling([], 2) == [("join", 2)]
    # draining/quiesced replicas are neither active nor reclaimable
    assert plan_fleet_scaling([_snap(0, "draining"), _snap(1, "quiesced"),
                               _snap(2, "active")], 1) == []


def test_plan_fleet_scaling_deterministic_order():
    snap = [_snap(3, "dead"), _snap(1, "dead"),
            _snap(0, "active", inflight=1), _snap(2, "active", inflight=1)]
    a = plan_fleet_scaling(snap, 0)
    assert a == plan_fleet_scaling(list(reversed(snap)), 0)
    # ties on load drain the NEWEST replica first
    assert a == [("release", 1), ("release", 3),
                 ("drain", 2), ("drain", 0)]


# ---------------------------------------------------------------------------
# in-process fleet: routing, cohort wholeness, drain/join (tier-1 fast)
# ---------------------------------------------------------------------------

def _inproc_fleet(n=3, **replica_kw):
    gw = ServiceGateway("mpklink_opt")
    for i in range(n):
        gw.register_replica("echo", _tagged(i), transport="mpklink_opt",
                            **replica_kw)
    return gw.start()


def test_fleet_routes_across_replicas():
    gw = _inproc_fleet(3)
    try:
        cli = gw.connect("c0")
        seen = set()
        for _ in range(40):
            out = cli.call("echo", np.arange(4, dtype=np.uint8))
            assert np.asarray(out)[:4].tolist() == [0, 1, 2, 3]
            seen.add(_tag(out))
        assert len(seen) >= 2, seen
        snap = gw.fleet_stats()["echo"]
        assert sum(s["served"] for s in snap) == 40
        assert all(s["state"] == "active" and s["inflight"] == 0
                   for s in snap)
        cli.close()
    finally:
        gw.close()


def test_fleet_cohorts_never_split():
    """A batch envelope lands WHOLE on one replica — every item of every
    cohort carries the same replica tag, across many cohorts."""
    gw = _inproc_fleet(3)
    try:
        cli = gw.connect("c0")
        tags_per_cohort = []
        for k in range(12):
            outs = cli.call_batch("echo",
                                  [np.arange(3, dtype=np.uint8)] * (4 + k))
            tags = {_tag(o) for o in outs}
            assert len(tags) == 1, f"cohort {k} split across replicas {tags}"
            tags_per_cohort.append(tags.pop())
        assert len(set(tags_per_cohort)) >= 2, tags_per_cohort
        assert gw.fleet("echo").stats["cohorts"] == 12
        cli.close()
    finally:
        gw.close()


def test_fleet_coalesced_cohorts_never_split():
    """Auto-coalesced inline calls (the mux's scatter cohort) reach the
    fleet through the same batch path and stay on one replica per
    cohort."""
    gw = _inproc_fleet(3)
    gw.enable_coalescing(max_wait_us=2000.0)
    try:
        clients = [gw.connect(f"c{i}") for i in range(8)]
        results = [None] * 8
        start = threading.Barrier(8)

        def caller(i):
            start.wait()
            results[i] = clients[i].call("echo",
                                         np.arange(2, dtype=np.uint8))

        threads = [threading.Thread(target=caller, args=(i,))
                   for i in range(8)]
        for t in threads:
            t.start()
        for t in threads:
            t.join(timeout=60)
        assert all(r is not None for r in results)
        tags = {_tag(r) for r in results}
        fleet = gw.fleet("echo")
        # every admission unit (coalesced cohort or single call) stayed
        # whole: one routing decision = one replica, so the distinct
        # replica tags observed can never exceed the router's pick count
        assert len(tags) <= fleet.router.picks
        assert fleet.stats["routed"] == 8
        for c in clients:
            c.close()
    finally:
        gw.close()


def test_fleet_drain_then_join_rekeys_once():
    """Drain: the drained replica quiesces and never serves again; join:
    the service-domain epoch bumps exactly ONCE and traffic continues
    (clients transparently re-key on their next call)."""
    gw = _inproc_fleet(2)
    try:
        cli = gw.connect("c0")
        for _ in range(10):
            cli.call("echo", np.arange(2, dtype=np.uint8))
        svc = gw._services["echo"]
        epoch0 = gw.registry.epoch(svc.domain)
        assert gw.drain_replica("echo", 0, timeout=10.0)
        assert gw.registry.epoch(svc.domain) == epoch0 + 1
        snap = {s["rid"]: s for s in gw.fleet_stats()["echo"]}
        assert snap[0]["state"] == "quiesced"
        for _ in range(10):
            assert _tag(cli.call("echo", np.arange(2, dtype=np.uint8))) == 1
        # join under live traffic: one more epoch bump, then the new
        # replica joins the routing set
        epoch1 = gw.registry.epoch(svc.domain)
        rid = gw.register_replica("echo", _tagged(7),
                                  transport="mpklink_opt")
        assert gw.registry.epoch(svc.domain) == epoch1 + 1
        seen = set()
        for _ in range(40):
            seen.add(_tag(cli.call("echo", np.arange(2, dtype=np.uint8))))
        assert seen == {1, 7}, seen
        assert rid == 2
        cli.close()
    finally:
        gw.close()


def test_fleet_and_plain_service_names_do_not_mix():
    gw = ServiceGateway("mpklink_opt")
    try:
        gw.register_service("plain", _tagged(0))
        with pytest.raises(ValueError, match="plain"):
            gw.register_replica("plain", _tagged(1))
        with pytest.raises(KeyError):
            gw.fleet("nope")
    finally:
        gw.close()


def test_fleet_all_replicas_gone_is_typed_unavailable():
    gw = _inproc_fleet(1)
    try:
        cli = gw.connect("c0")
        cli.call("echo", np.arange(2, dtype=np.uint8))
        assert gw.drain_replica("echo", 0, timeout=10.0)
        with pytest.raises(ServiceUnavailable):
            cli.call("echo", np.arange(2, dtype=np.uint8))
        cli.close()
    finally:
        gw.close()


# ---------------------------------------------------------------------------
# proc-backed fleet: real children, drain zero-loss, kill -9 chaos
# ---------------------------------------------------------------------------

def _slow_tagged(i, sleep_s=0.004):
    def handler(req):
        time.sleep(sleep_s)
        return np.concatenate([np.asarray(req, np.uint8),
                               np.array([i], np.uint8)])
    return handler


def _proc_fleet(n, handler_factory=_tagged, service="echo"):
    gw = ServiceGateway("mpklink_opt")
    for i in range(n):
        gw.register_replica(service, handler_factory(i),
                            transport_kwargs=dict(_PROC_KW))
    return gw.start()


@pytest.mark.proc
def test_fleet_proc_drain_loses_zero_inflight():
    """Drain a proc replica while 4 client threads hammer the service:
    every request completes correctly (the draining replica finishes its
    admitted work, new work routes to the survivor), and the drained
    replica ends quiesced with its child gone."""
    gw = _proc_fleet(2, _slow_tagged)
    errors, tags = [], []
    stop = threading.Event()
    try:
        def worker(i):
            cli = gw.connect(f"c{i}")
            try:
                for k in range(25):
                    out = cli.call("echo", np.arange(3, dtype=np.uint8))
                    assert np.asarray(out)[:3].tolist() == [0, 1, 2]
                    tags.append(_tag(out))
            except Exception as e:      # pragma: no cover - fails below
                errors.append(f"client {i}: {type(e).__name__}: {e}")
            finally:
                cli.close()

        threads = [threading.Thread(target=worker, args=(i,))
                   for i in range(4)]
        for t in threads:
            t.start()
        while len(tags) < 20 and not errors:    # live traffic established
            time.sleep(0.005)
        assert gw.drain_replica("echo", 0, timeout=20.0)
        for t in threads:
            t.join(timeout=60)
        assert not errors, errors
        assert len(tags) == 100
        snap = {s["rid"]: s for s in gw.fleet_stats()["echo"]}
        assert snap[0]["state"] == "quiesced"
        assert snap[0]["inflight"] == 0
        # everything admitted after the drain decision ran on the survivor
        assert tags and tags[-1] == 1
    finally:
        stop.set()
        gw.close()


@pytest.mark.proc
def test_fleet_proc_join_under_live_traffic():
    """Scale out mid-traffic: a replica forked and registered while 3
    clients are in flight serves real requests after exactly one epoch
    re-key, with zero client-visible errors."""
    gw = _proc_fleet(1, _slow_tagged)
    errors, tags = [], []
    try:
        def worker(i):
            cli = gw.connect(f"c{i}")
            try:
                for _ in range(30):
                    tags.append(_tag(cli.call(
                        "echo", np.arange(2, dtype=np.uint8))))
            except Exception as e:      # pragma: no cover - fails below
                errors.append(f"client {i}: {type(e).__name__}: {e}")
            finally:
                cli.close()

        threads = [threading.Thread(target=worker, args=(i,))
                   for i in range(3)]
        for t in threads:
            t.start()
        while len(tags) < 10 and not errors:
            time.sleep(0.005)
        svc = gw._services["echo"]
        epoch0 = gw.registry.epoch(svc.domain)
        gw.register_replica("echo", _slow_tagged(1),
                            transport_kwargs=dict(_PROC_KW))
        assert gw.registry.epoch(svc.domain) == epoch0 + 1
        for t in threads:
            t.join(timeout=120)
        assert not errors, errors
        assert len(tags) == 90
        assert set(tags) == {0, 1}, set(tags)
    finally:
        gw.close()


@pytest.mark.proc
def test_fleet_proc_kill9_chaos():
    """kill -9 one replica child mid-burst: the ONLY client-visible
    failures are typed ServiceCrashed on items that were truly in flight
    on the victim's wire; the router never picks the victim again; the
    survivors keep serving with bounded tail latency."""
    gw = _proc_fleet(3, _slow_tagged)
    outcomes = []                       # (kind, value) per call, all threads
    lock = threading.Lock()
    killed = threading.Event()
    try:
        fleet = gw.fleet("echo")
        # force the forks now so the victim has a child to kill
        warm = gw.connect("warm")
        for _ in range(9):
            warm.call("echo", np.arange(2, dtype=np.uint8))
        warm.close()

        def worker(i):
            cli = gw.connect(f"c{i}")
            try:
                for _ in range(30):
                    t0 = time.perf_counter()
                    try:
                        out = cli.call("echo",
                                       np.arange(2, dtype=np.uint8))
                        rec = ("ok", time.perf_counter() - t0, _tag(out))
                    except ServiceCrashed:
                        rec = ("crashed", time.perf_counter() - t0, None)
                    with lock:
                        outcomes.append(rec + (killed.is_set(),))
            except Exception as e:      # pragma: no cover - fails below
                with lock:
                    outcomes.append(("fatal",
                                     f"{type(e).__name__}: {e}", None,
                                     killed.is_set()))
            finally:
                cli.close()

        threads = [threading.Thread(target=worker, args=(i,))
                   for i in range(6)]
        for t in threads:
            t.start()
        while len(outcomes) < 30:
            time.sleep(0.002)
        victim = fleet._replicas[1]
        os.kill(victim.session._proc.pid, signal.SIGKILL)
        killed.set()
        for t in threads:
            t.join(timeout=120)

        fatal = [o for o in outcomes if o[0] == "fatal"]
        assert not fatal, fatal
        crashed = [o for o in outcomes if o[0] == "crashed"]
        ok_after = [o for o in outcomes if o[0] == "ok" and o[3]]
        # typed ServiceCrashed only for the victim's truly in-flight items:
        # the wire carries at most one request per replica at a time, and
        # queued-but-unsent work re-routes, so failures stay rare
        assert len(crashed) <= 6, outcomes
        snap = {s["rid"]: s for s in gw.fleet_stats()["echo"]}
        assert snap[1]["state"] == "dead" and snap[1]["crashes"] == 1
        # post-kill traffic kept flowing with bounded tail latency (a few
        # pre-kill victim responses may still land after the flag flips —
        # that's the kill racing the last served request, not a route)
        assert ok_after, "no post-kill traffic observed"
        p99 = float(np.percentile([o[1] for o in ok_after], 99))
        assert p99 < 2.0, f"survivor p99 {p99 * 1e3:.1f}ms"
        # router never picks the dead replica again: every fresh probe
        # lands on a survivor
        probe = gw.connect("probe")
        probe_tags = {_tag(probe.call("echo", np.arange(2, dtype=np.uint8)))
                      for _ in range(20)}
        probe.close()
        assert probe_tags <= {0, 2} and probe_tags, probe_tags
        # the supervisor policy reclaims the corpse deterministically
        assert ("release", 1) in plan_fleet_scaling(
            gw.fleet_stats()["echo"], 2)
        assert gw.drain_replica("echo", 1, timeout=10.0)
    finally:
        gw.close()


@pytest.mark.proc
def test_fleet_proc_batch_cohort_on_one_child():
    """Cohort admission holds across process boundaries: a pipelined
    batch rides ONE replica's ring even with several proc replicas up."""
    gw = _proc_fleet(2)
    try:
        cli = gw.connect("c0")
        for k in range(6):
            outs = cli.call_batch("echo",
                                  [np.arange(4, dtype=np.uint8)] * 6)
            assert len({_tag(o) for o in outs}) == 1
        assert gw.fleet("echo").stats["cohorts"] == 6
        cli.close()
    finally:
        gw.close()


@pytest.mark.proc
def test_fleet_proc_wordcount_end_to_end():
    """The paper's workload over a 3-replica proc fleet: every answer
    exact, load observed on more than one child."""
    gw = ServiceGateway("mpklink_opt")
    for _ in range(3):
        gw.register_replica("wc", lambda req: wordcount_handler(req),
                            transport_kwargs=dict(_PROC_KW))
    gw.start()
    try:
        cli = gw.connect("c0")
        for n in (10, 100, 350):
            for s in range(4):
                text = make_text(n, seed=s)
                assert parse_count(np.asarray(cli.call("wc", text))) == n
        snap = gw.fleet_stats()["wc"]
        assert sum(s["served"] for s in snap) == 12
        assert sum(1 for s in snap if s["served"]) >= 2
        cli.close()
    finally:
        gw.close()
