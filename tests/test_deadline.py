"""Deadline propagation, retry budgets, and overload brownout
(docs/protocol.md §9): the client's remaining budget rides the envelope
as a MAC-covered meta word, every hop computes against it, expired work
is shed BEFORE execution with a typed ``DeadlineExpired``, and an
overloaded service sheds admissions with a typed ``Overloaded`` instead
of queueing into timeout collapse.

Everything here is in-process and tier-1; the proc-backed supervisor and
kill -9 matrices live in tests/test_supervisor.py."""
import threading
import time

import numpy as np
import pytest

from repro.core import ServiceGateway, framing
from repro.core.gateway import RetryBudget, _Brownout
from repro.core.transports import (DeadlineExpired, Overloaded,
                                   ResponseTimeout, ServiceUnavailable)
from repro.core.wordcount import make_text, parse_count, wordcount_handler


# ---------------------------------------------------------------------------
# the deadline word itself: lane 10, MAC-covered, saturating encode
# ---------------------------------------------------------------------------

def test_deadline_to_us_encoding():
    """None → 0 (no deadline), already-expired → 1 (minimum non-zero so
    'expired' survives the wire), huge → saturates at the lane max."""
    assert framing.deadline_to_us(None) == 0
    assert framing.deadline_to_us(0.0) == 1
    assert framing.deadline_to_us(-5.0) == 1
    assert framing.deadline_to_us(1.0) == 1_000_000
    assert framing.deadline_to_us(1e9) == framing.DEADLINE_US_MAX


def test_deadline_word_rides_the_frame():
    arr = make_text(9, seed=0)
    f = framing.build_frame(arr, seed=0xAB, seq=3, deadline_us=123_456)
    assert framing.frame_deadline_us(f) == 123_456
    out = framing.parse_frame(f, seed=0xAB, expect_seq=3)
    np.testing.assert_array_equal(out, arr)


def test_deadline_word_is_mac_covered():
    """An attacker cannot extend (or shrink) a propagated deadline in
    flight: flipping lane 10 after sealing breaks MAC verification."""
    f = framing.build_frame(make_text(5, seed=1), seed=0xAB, seq=1,
                            deadline_us=50_000)
    f[0][framing.DEADLINE_LANE] = framing.DEADLINE_US_MAX
    with pytest.raises(framing.FrameError):
        framing.parse_frame(f, seed=0xAB, expect_seq=1)


def test_frame_without_deadline_reads_zero():
    f = framing.build_frame(make_text(5, seed=2), seed=0xAB, seq=1)
    assert framing.frame_deadline_us(f) == 0


# ---------------------------------------------------------------------------
# server-side shed: expired work never reaches the handler
# ---------------------------------------------------------------------------

def _gw(**kw):
    gw = ServiceGateway("mpklink_opt", **kw)
    gw.register_service("wordcount", wordcount_handler)
    return gw.start()


def test_expired_work_shed_before_execution():
    """_run_guarded sheds a request whose propagated deadline has already
    passed: typed DeadlineExpired, the handler never runs, and the
    gateway's ``expired`` counter records the shed."""
    ran = []
    gw = ServiceGateway("mpklink_opt")
    gw.register_service("probe", lambda req: (ran.append(1),
                                              np.asarray(req))[1])
    gw.start()
    try:
        svc = gw._services["probe"]
        with pytest.raises(DeadlineExpired):
            gw._run_guarded(svc, np.zeros(3, np.uint8),
                            deadline=time.monotonic() - 0.5)
        assert ran == []
        assert gw.stats["expired"] == 1
        # an unexpired deadline admits normally
        out = gw._run_guarded(svc, np.arange(3, dtype=np.uint8),
                              deadline=time.monotonic() + 30.0)
        assert np.asarray(out).tolist() == [0, 1, 2]
        assert ran == [1]
    finally:
        gw.close()


def test_client_zero_budget_fails_typed_without_send():
    """timeout=0 expires at the loop top — typed DeadlineExpired, no wire
    traffic, no handler execution."""
    gw = _gw()
    try:
        c = gw.connect("c0")
        before = gw.stats["requests"]
        with pytest.raises(DeadlineExpired):
            c.call("wordcount", make_text(4, seed=0), timeout=0)
        assert gw.stats["requests"] == before
        c.close()
    finally:
        gw.close()


def test_deadline_expired_is_a_response_timeout():
    """DeadlineExpired subclasses ResponseTimeout: callers netting the
    liveness family catch it, callers wanting the typed distinction get
    it. It must NOT read as overload."""
    assert issubclass(DeadlineExpired, ResponseTimeout)
    assert not issubclass(DeadlineExpired, ServiceUnavailable)
    assert issubclass(Overloaded, ServiceUnavailable)


# ---------------------------------------------------------------------------
# the mux regression (ISSUE 9 satellite): deadline rides through the
# coalescer — a 1s budget fails typed in ~1s, not the old +30s slack
# ---------------------------------------------------------------------------

def test_mux_deadline_fails_in_about_one_second():
    """A 1s-deadline call through the coalescer against a wedged service
    must fail TYPED in roughly the budget, not the carrier's old
    ``transport.timeout * 2 + 30.0`` liveness slack."""
    release = threading.Event()

    def wedged(req):
        release.wait(20.0)
        return np.asarray(req)

    gw = ServiceGateway("mpklink_opt")
    gw.register_service("wedged", wedged)
    gw.start()
    gw.enable_coalescing(max_wait_us=500.0)
    try:
        c = gw.connect("c0")
        t0 = time.monotonic()
        with pytest.raises(ResponseTimeout):
            c.call("wedged", np.arange(4, dtype=np.uint8), timeout=1.0)
        elapsed = time.monotonic() - t0
        # budget + one coalescing window + scheduling slack — nowhere
        # near the old 30s constant
        assert elapsed < 5.0, f"took {elapsed:.1f}s; old +30.0 bound back?"
        c.close()
    finally:
        release.set()
        gw.close()


def test_mux_calls_without_deadline_still_complete():
    """No-deadline traffic through the mux is unaffected by the derived
    liveness bound."""
    gw = _gw()
    gw.enable_coalescing(max_wait_us=500.0)
    try:
        c = gw.connect("c0")
        for n in (5, 9, 13):
            assert parse_count(c.call("wordcount",
                                      make_text(n, seed=n))) == n
        c.close()
    finally:
        gw.close()


# ---------------------------------------------------------------------------
# retry budget: token bucket over EXTRA attempts
# ---------------------------------------------------------------------------

def test_retry_budget_burst_then_dry():
    b = RetryBudget(ratio=0.1, burst=3)
    assert [b.take() for _ in range(3)] == [True] * 3
    assert b.take() is False
    assert b.spent == 3 and b.denied == 1


def test_retry_budget_earns_from_primaries():
    b = RetryBudget(ratio=0.25, burst=3, initial=0.0)
    assert b.take() is False
    for _ in range(4):
        b.note_primary()
    assert b.take() is True             # 4 primaries × 0.25 = 1 token
    assert b.take() is False


def test_retry_budget_caps_at_burst():
    b = RetryBudget(ratio=1.0, burst=2)
    for _ in range(50):
        b.note_primary()
    assert b.tokens() == 2.0


def test_retry_budget_rejects_bad_config():
    with pytest.raises(ValueError):
        RetryBudget(ratio=-0.1)
    with pytest.raises(ValueError):
        RetryBudget(burst=0)


def test_client_retries_draw_from_budget():
    """A client with a dry budget cannot retry even when ``retries`` says
    it may: the bucket is the binding cap on extra attempts."""
    gw = _gw()
    calls = {"n": 0}
    real = gw._services["wordcount"].handler

    def flaky(req):
        calls["n"] += 1
        if calls["n"] == 1:
            raise ResponseTimeout("injected")
        return real(req)

    gw._services["wordcount"].handler = flaky
    try:
        budget = RetryBudget(ratio=0.0, burst=1, initial=0.0)
        c = gw.connect("c0", retries=3, retry_budget=budget)
        with pytest.raises(ResponseTimeout):
            c.call("wordcount", make_text(6, seed=0))
        assert budget.denied >= 1 and budget.spent == 0
        c.close()
        # with tokens, the same failure heals on the retry
        calls["n"] = 0
        budget2 = RetryBudget(ratio=0.1, burst=3)
        c2 = gw.connect("c1", retries=3, retry_budget=budget2)
        assert parse_count(c2.call("wordcount", make_text(6, seed=0))) == 6
        assert budget2.spent == 1
        c2.close()
    finally:
        gw.close()


# ---------------------------------------------------------------------------
# brownout: hysteretic typed shedding
# ---------------------------------------------------------------------------

def test_brownout_hysteresis():
    """Trips at high water, sheds until drained to LOW water — no
    flapping at the boundary."""
    bo = _Brownout(high_water=4, low_water=2)
    for _ in range(4):
        bo.admit("svc")
    with pytest.raises(Overloaded):
        bo.admit("svc")                 # at high water: engaged
    bo.done(1, 5.0)                     # inflight 3 — still above low
    with pytest.raises(Overloaded):
        bo.admit("svc")
    bo.done(1, 5.0)                     # inflight 2 == low water: recover
    bo.admit("svc")
    snap = bo.snapshot()
    assert snap["engagements"] == 1 and snap["sheds"] == 2
    assert not snap["engaged"]


def test_brownout_retry_after_estimate():
    bo = _Brownout(high_water=2, low_water=1)
    bo.admit("svc")
    bo.done(1, 100.0)                   # seed the EWMA at 100ms
    bo.admit("svc")
    bo.admit("svc")
    with pytest.raises(Overloaded) as ei:
        bo.admit("svc")
    assert ei.value.retry_after > 0.0


def test_brownout_ewma_gate():
    """high_water_ms engages on service time alone, and recovery requires
    the EWMA to fall back below the gate."""
    bo = _Brownout(high_water=1000, low_water=1, high_water_ms=50.0)
    bo.admit("svc")
    bo.done(1, 200.0)                   # EWMA jumps past the gate
    with pytest.raises(Overloaded):
        bo.admit("svc")
    # completions drag the EWMA back under 50ms → recovery
    for _ in range(30):
        bo.inflight += 1
        bo.done(1, 1.0)
    bo.admit("svc")


def test_brownout_rejects_bad_water_marks():
    with pytest.raises(ValueError):
        _Brownout(high_water=4, low_water=8)
    with pytest.raises(ValueError):
        _Brownout(high_water=4, low_water=0)


def test_overloaded_sheds_typed_over_the_wire():
    """End to end: a saturated service sheds the next admission with a
    typed Overloaded carrying retry_after, reconstructed on the client
    side of the wire; hysteretic recovery admits again after the drain."""
    gate = threading.Event()

    def blocking(req):
        gate.wait(10.0)
        return np.asarray(req)

    gw = ServiceGateway("mpklink_opt")
    gw.register_service("busy", blocking)
    gw.start()
    gw.enable_brownout("busy", high_water=1, low_water=1)
    try:
        c = gw.connect("c0")
        holder = threading.Thread(
            target=lambda: c.call("busy", np.zeros(2, np.uint8)))
        holder.start()
        deadline = time.monotonic() + 5.0
        caught = None
        while time.monotonic() < deadline:
            try:
                gw.connect("probe").call("busy", np.zeros(2, np.uint8),
                                         timeout=0.5)
            except Overloaded as e:
                caught = e
                break
            except ResponseTimeout:
                continue
        gate.set()
        holder.join(timeout=10)
        assert caught is not None, "brownout never engaged"
        assert hasattr(caught, "retry_after")
        assert gw.stats["overloaded"] >= 1
        # hysteretic recovery: with the holder drained, admissions resume
        out = c.call("busy", np.arange(3, dtype=np.uint8), timeout=5.0)
        assert np.asarray(out).tolist() == [0, 1, 2]
        c.close()
    finally:
        gate.set()
        gw.close()


def test_enable_brownout_is_single_shot():
    gw = _gw()
    try:
        gw.enable_brownout("wordcount", high_water=8)
        with pytest.raises(RuntimeError):
            gw.enable_brownout("wordcount", high_water=8)
    finally:
        gw.close()


def test_overloaded_not_retried_without_budget():
    """Overloaded with retries=0 surfaces immediately — a shedding
    service must not be hammered by the default client."""
    gw = _gw()
    gw.enable_brownout("wordcount", high_water=1, low_water=1)
    bo = gw._services["wordcount"].brownout
    bo.engaged = True
    bo.inflight = 5
    try:
        c = gw.connect("c0")
        t0 = time.monotonic()
        with pytest.raises(Overloaded):
            c.call("wordcount", make_text(4, seed=0))
        assert time.monotonic() - t0 < 1.0
        c.close()
    finally:
        gw.close()
