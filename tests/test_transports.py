"""The paper's IPC transport zoo: correctness, capacity failure, sync counts."""
import numpy as np
import pytest

from repro.core import TRANSPORTS
from repro.core.transports import (CapacityError, MPKLinkOptTransport,
                                   MPKLinkTransport, ShmTransport)
from repro.core.wordcount import (count_words, make_text, parse_count,
                                  wordcount_handler)


@pytest.mark.parametrize("n", [1, 2, 100, 1000])
def test_make_text_exact_counts(n):
    assert int(count_words(make_text(n, seed=n))[0]) == n


@pytest.mark.parametrize("name", sorted(TRANSPORTS))
def test_roundtrip(name):
    tr = TRANSPORTS[name](wordcount_handler)
    tr.start()
    try:
        # 20_000 words ≈ 140 KB > the grpc_sim 64 KiB flow-control window —
        # exercises the WINDOW_UPDATE path (regression: a pending update
        # header was once misread as a data frame and deadlocked)
        for n in (1, 100, 1000, 20_000):
            if name == "shm" and n == 20_000:
                continue                          # within capacity, but keep fast
            resp = tr.request(make_text(n, seed=n))
            assert parse_count(np.asarray(resp)) == n, name
    finally:
        tr.close()


def test_shm_capacity_failure():
    """Paper §VII: the raw shm baseline is incapable of ≥100k-word requests."""
    tr = ShmTransport(wordcount_handler)
    tr.start()
    try:
        assert parse_count(np.asarray(tr.request(make_text(10_000, seed=1)))) == 10_000
        with pytest.raises(CapacityError):
            tr.request(make_text(100_000, seed=2))
    finally:
        tr.close()


def test_mpklink_sync_scaling():
    """Key syncs grow with payload for the paper-faithful transport (the
    large-payload cliff §VII/§IX) and stay O(1) for the batched variant."""
    tr = MPKLinkTransport(wordcount_handler)
    tr.start()
    try:
        tr.request(make_text(100, seed=1))
        small = tr.sync_count
        tr.request(make_text(200_000, seed=2))
        large = tr.sync_count - small
    finally:
        tr.close()
    assert small <= 3
    assert large > 10 * small

    opt = MPKLinkOptTransport(wordcount_handler)
    opt.start()
    try:
        opt.request(make_text(100, seed=1))
        s = opt.sync_count
        opt.request(make_text(200_000, seed=2))
        l = opt.sync_count - s
    finally:
        opt.close()
    assert l <= 3                                 # one data sync + one response


def test_mpklink_multiple_sequenced_requests():
    tr = MPKLinkTransport(wordcount_handler)
    tr.start()
    try:
        for i, n in enumerate((10, 500, 50)):
            assert parse_count(np.asarray(tr.request(make_text(n, seed=i)))) == n
        assert tr._seq == 3
    finally:
        tr.close()
