"""Runtime: trainer restart semantics, stragglers, serving engine, elastic."""
import tempfile

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import OptimizerConfig, TrainConfig, get_reduced
from repro.models import init_params
from repro.models.transformer import Impl
from repro.runtime import (FailureInjector, HeartbeatMonitor, Request,
                           ServingEngine, StragglerDetector, Trainer,
                           plan_remesh)

IMPL = Impl(attention="naive", remat=False)
TCFG = TrainConfig(microbatch_size=2, dtype="float32",
                   optimizer=OptimizerConfig(lr=1e-3, warmup_steps=2,
                                             total_steps=50),
                   log_every=0, checkpoint_every=3, keep_checkpoints=2)


def test_training_reduces_loss():
    cfg = get_reduced("smollm-360m")
    tr = Trainer(cfg, TCFG, global_batch=4, seq_len=32, impl=IMPL)
    rep = tr.run(20)
    first = np.mean(rep.losses[:4])
    last = np.mean(rep.losses[-4:])
    assert last < first, (first, last)


def test_restart_equivalence():
    """A failed+restarted run ends on the same trajectory as a clean run."""
    cfg = get_reduced("llama3.2-1b")
    with tempfile.TemporaryDirectory() as d:
        inj = FailureInjector({5: ["w1"]})
        tr = Trainer(cfg, TCFG, global_batch=4, seq_len=16, checkpoint_dir=d,
                     impl=IMPL, workers=["w0", "w1"], injector=inj)
        rep = tr.run(8)
        assert rep.restarts == 1
    clean = Trainer(cfg, TCFG, global_batch=4, seq_len=16, impl=IMPL)
    rep2 = clean.run(8)
    assert abs(rep.losses[-1] - rep2.losses[-1]) < 1e-4


def test_heartbeat_detection():
    mon = HeartbeatMonitor(["a", "b"], timeout=10.0)
    t0 = 1000.0
    mon.beat("a", at=t0)
    mon.beat("b", at=t0)
    assert mon.check(at=t0 + 5) == set()
    mon.beat("a", at=t0 + 11)
    assert mon.check(at=t0 + 12) == {"b"}
    assert mon.alive() == ["a"]


def test_straggler_detector():
    det = StragglerDetector(window=16, factor=2.0)
    flags = [det.observe(0.1) for _ in range(10)]
    assert not any(flags)
    assert det.observe(0.5)                       # 5× the median
    assert not det.observe(0.11)


def test_plan_remesh():
    assert plan_remesh(256, tp=16) == ((16, 16), ("data", "model"))
    assert plan_remesh(255, tp=16) == ((15, 16), ("data", "model"))
    assert plan_remesh(15, tp=16) is None


def test_guard_trip_recovers_from_checkpoint():
    """A tripped channel guard (corrupted exchange) restores the last
    checkpoint and resumes — same machinery as worker failures."""
    cfg = get_reduced("llama3.2-1b")
    with tempfile.TemporaryDirectory() as d:
        tr = Trainer(cfg, TCFG, global_batch=4, seq_len=16, checkpoint_dir=d,
                     impl=IMPL)
        real_fn = tr._fn()
        trip_at = {"step": 5, "armed": True}

        def wrapped(params, opt, batch):
            p, o, m = real_fn(params, opt, batch)
            m = dict(m)
            if trip_at["armed"] and int(tr.straggler._times.maxlen or 0) >= 0 \
                    and len(tr.straggler._times) == trip_at["step"]:
                m["guard_ok"] = 0.0
                trip_at["armed"] = False
            return p, o, m

        tr._step_fn = wrapped
        rep = tr.run(10)
        assert rep.guard_trips == 1
        assert any("guard tripped" in e for e in rep.events)
        assert rep.steps_run >= 10


def test_serving_continuous_batching():
    cfg = get_reduced("llama3.2-1b")
    params = init_params(cfg, jax.random.PRNGKey(0))
    eng = ServingEngine(cfg, params, max_batch=4, max_seq=64, impl=IMPL)
    for i in range(6):
        eng.submit(Request(rid=i, prompt=[1 + i, 2, 3], max_new=5))
    done = eng.run_until_drained()
    assert len(done) == 6
    assert all(len(r.generated) == 5 for r in done)
    # batching actually happened: fewer ticks than sequential execution
    assert eng.ticks < 6 * (3 + 5)


def test_serving_determinism_vs_decode():
    """Engine output for one request == plain greedy decode."""
    from repro.models import decode_step, init_decode_state
    cfg = get_reduced("mamba2-1.3b")
    params = init_params(cfg, jax.random.PRNGKey(0))
    prompt, n_new = [5, 9, 2], 4

    eng = ServingEngine(cfg, params, max_batch=2, max_seq=32, impl=IMPL)
    eng.submit(Request(rid=0, prompt=prompt, max_new=n_new))
    done = eng.run_until_drained()

    st = init_decode_state(cfg, params, 1, 32, dtype=jnp.float32, impl=IMPL)
    toks = list(prompt)
    out = []
    for t in range(len(prompt) + n_new - 1):
        cur = jnp.asarray([[toks[t] if t < len(toks) else out[-1]]], jnp.int32)
        lg, st = decode_step(cfg, params, st, cur, impl=IMPL, dtype=jnp.float32)
        nxt = int(jnp.argmax(lg[0, -1]))
        if t >= len(prompt) - 1:
            out.append(nxt)
    assert done[0].generated == out
