import os
import sys

# Tests run on the single real CPU device. Only the dry-run sets the
# 512-device flag (in its own process); multi-device tests here spawn
# subprocesses with their own XLA_FLAGS.
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import jax
import numpy as np
import pytest


@pytest.fixture
def rng():
    return np.random.default_rng(0)


@pytest.fixture
def key():
    return jax.random.PRNGKey(0)
