import gc
import os
import sys
import time

# Tests run on the single real CPU device. Only the dry-run sets the
# 512-device flag (in its own process); multi-device tests here spawn
# subprocesses with their own XLA_FLAGS.
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import jax
import numpy as np
import pytest


@pytest.fixture
def rng():
    return np.random.default_rng(0)


@pytest.fixture
def key():
    return jax.random.PRNGKey(0)


@pytest.fixture(autouse=True, scope="module")
def proc_hygiene(request):
    """Per-module leak detector for the process-backed suites: after every
    test module, this process must own zero ``/dev/shm/mpk_<pid>_*``
    segments, zero unreaped service children, AND zero doorbell socketpair
    fds (procwire's per-session ledger). procwire defers the final
    segment close of a crashed child (the crash invariant pins in-flight
    slots), so the check first reaps (``active_children`` joins finished
    processes) and sweeps the deferred-close list, with a short retry loop
    for teardowns that are still settling — then fails loudly, naming the
    owning module, instead of letting a leak bill the next module's tests."""
    yield
    import multiprocessing

    from repro.core import procwire

    gc.collect()
    mine = f"mpk_{os.getpid()}_"
    deadline = time.monotonic() + 10.0
    while True:
        procwire._sweep_deferred_closes()
        kids = multiprocessing.active_children()
        segs = ([f for f in os.listdir("/dev/shm") if f.startswith(mine)]
                if os.path.isdir("/dev/shm") else [])
        bells = procwire.open_doorbell_fds()
        if not kids and not segs and not bells:
            return
        if time.monotonic() > deadline:
            pytest.fail(
                f"proc hygiene ({request.module.__name__}): unreaped "
                f"children={[k.pid for k in kids]} leaked shm "
                f"segments={segs} open doorbell fds={bells}")
        time.sleep(0.05)
