"""End-to-end behaviour tests for the paper's system.

The MPKLink claims (paper §VII + DESIGN.md §8), validated on the measurable
CPU reproduction, plus a full train→checkpoint→restart→serve lifecycle."""
import tempfile
import time

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import OptimizerConfig, TrainConfig, get_reduced
from repro.core import TRANSPORTS
from repro.core.transports import (CapacityError, MPKLinkOptTransport,
                                   MPKLinkTransport, ShmTransport)
from repro.core.wordcount import make_text, parse_count, wordcount_handler
from repro.models import init_params
from repro.models.transformer import Impl
from repro.runtime import FailureInjector, Request, ServingEngine, Trainer

IMPL = Impl(attention="naive", remat=False)


def test_paper_claim_all_transports_agree():
    """All five IPC methods compute identical word counts (correctness)."""
    text = make_text(5000, seed=0)
    counts = {}
    for name, cls in TRANSPORTS.items():
        tr = cls(wordcount_handler)
        tr.start()
        try:
            counts[name] = parse_count(np.asarray(tr.request(text)))
        finally:
            tr.close()
    assert set(counts.values()) == {5000}, counts


def test_paper_claim_shm_fails_100k_mpklink_survives():
    """§VII: baseline shm incapable ≥100k words; MPKLink's region design
    keeps working (Figure 3 discussion)."""
    big = make_text(100_000, seed=1)
    shm = ShmTransport(wordcount_handler)
    shm.start()
    try:
        with pytest.raises(CapacityError):
            shm.request(big)
    finally:
        shm.close()
    mpk = MPKLinkTransport(wordcount_handler)
    mpk.start()
    try:
        assert parse_count(np.asarray(mpk.request(big))) == 100_000
    finally:
        mpk.close()


def test_paper_claim_key_sync_overhead_grows():
    """§IX: MPKLink's large-payload degradation is the per-chunk key sync —
    sync count scales with payload; the batched variant removes it."""
    mpk = MPKLinkTransport(wordcount_handler)
    mpk.start()
    try:
        mpk.request(make_text(1000, seed=0))
        s1 = mpk.sync_count
        mpk.request(make_text(500_000, seed=1))
        s2 = mpk.sync_count - s1
    finally:
        mpk.close()
    assert s2 >= 20 * s1

    opt = MPKLinkOptTransport(wordcount_handler)
    opt.start()
    try:
        opt.request(make_text(1000, seed=0))
        o1 = opt.sync_count
        opt.request(make_text(500_000, seed=1))
        o2 = opt.sync_count - o1
    finally:
        opt.close()
    assert o2 <= o1 + 1


def test_paper_claim_mpklink_security_envelope():
    """MPKLink rejects frames under a wrong domain/session seed while raw
    shm accepts anything — the isolation claim that justifies the overhead."""
    from repro.core import framing
    arr = np.arange(100, dtype=np.int32)
    frame = framing.build_frame(arr, seed=0xAAA, seq=0)
    with pytest.raises(framing.FrameError):
        framing.parse_frame(frame, seed=0xBBB)


def test_lifecycle_train_checkpoint_restart_serve():
    cfg = get_reduced("smollm-360m")
    tcfg = TrainConfig(microbatch_size=2, dtype="float32",
                       optimizer=OptimizerConfig(lr=3e-3, warmup_steps=2,
                                                 total_steps=40),
                       log_every=0, checkpoint_every=4, keep_checkpoints=2)
    with tempfile.TemporaryDirectory() as d:
        inj = FailureInjector({6: ["host3"]})
        tr = Trainer(cfg, tcfg, global_batch=4, seq_len=24, checkpoint_dir=d,
                     impl=IMPL, workers=[f"host{i}" for i in range(4)],
                     injector=inj)
        rep = tr.run(24)
        assert rep.restarts == 1
        assert rep.steps_run >= 24
        assert np.mean(rep.losses[-5:]) < np.mean(rep.losses[:5])
        _, state = tr.restore_or_init()
    eng = ServingEngine(cfg, state["params"], max_batch=2, max_seq=48, impl=IMPL)
    eng.submit(Request(rid=0, prompt=[1, 2, 3], max_new=4))
    done = eng.run_until_drained()
    assert len(done[0].generated) == 4
