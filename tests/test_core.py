"""MPKLink control plane: domains/keys/PKRU, framing, signatures, CA."""
import numpy as np
import pytest

from repro.core import framing
from repro.core.ca import CertificateAuthority, enroll
from repro.core.domains import (AccessViolation, KeyRegistry, READ, RW, WRITE,
                                mac_seed)
from repro.core import signature as sig
from repro.core.transports import fast_mac


# -- domains / PKRU ----------------------------------------------------------

def test_domain_allocation_and_exhaustion():
    reg = KeyRegistry(max_keys=4)
    doms = [reg.allocate_domain(f"d{i}") for i in range(4)]
    assert len({d.did for d in doms}) == 4
    with pytest.raises(ResourceWarning):
        reg.allocate_domain("overflow")          # pkey_alloc ENOSPC analogue


def test_rights_enforced():
    reg = KeyRegistry()
    dom = reg.allocate_domain("c")
    ro = reg.issue_key(dom, READ)
    reg.check(ro, READ)
    with pytest.raises(AccessViolation):
        reg.check(ro, WRITE)
    with pytest.raises(AccessViolation):
        reg.check(ro, RW)


def test_revocation_and_epoch():
    reg = KeyRegistry()
    dom = reg.allocate_domain("c")
    k1 = reg.issue_key(dom, RW)
    k2 = reg.issue_key(dom, RW)
    reg.check(k1, RW)
    reg.revoke(k1)
    with pytest.raises(AccessViolation):
        reg.check(k1, READ)                       # revoked
    with pytest.raises(AccessViolation):
        reg.check(k2, READ)                       # stale epoch after revoke
    k3 = reg.issue_key(dom, RW)
    reg.check(k3, RW)                             # fresh key at new epoch


def test_foreign_registry_key_rejected():
    reg_a, reg_b = KeyRegistry(seed=1), KeyRegistry(seed=2)
    dom_b = reg_b.allocate_domain("b")
    key_b = reg_b.issue_key(dom_b)
    with pytest.raises(AccessViolation):
        reg_a.check(key_b, READ)


def test_pkru_word_layout():
    reg = KeyRegistry()
    d0 = reg.allocate_domain("d0")
    d1 = reg.allocate_domain("d1")
    k0 = reg.issue_key(d0, RW)
    k1 = reg.issue_key(d1, READ)
    word = reg.pkru_word((k0, k1))
    assert (word >> 0) & 0b11 == 0b00             # RW
    assert (word >> 2) & 0b11 == 0b10             # read-only: write-disable
    assert (word >> 4) & 0b11 == 0b11             # unallocated: no access


# -- framing ------------------------------------------------------------------

@pytest.mark.parametrize("shape,dtype", [
    ((7,), np.float32), ((3, 5), np.int32), ((2, 2, 2), np.uint32),
    ((1,), np.float64), ((128,), np.uint8), ((4, 129), np.float32)])
def test_frame_roundtrip(shape, dtype):
    rng = np.random.default_rng(0)
    arr = (rng.standard_normal(shape) * 100).astype(dtype)
    frame = framing.build_frame(arr, seed=0xAB, seq=3)
    out = framing.parse_frame(frame, seed=0xAB, expect_seq=3)
    np.testing.assert_array_equal(out, arr)
    assert out.dtype == arr.dtype


def test_frame_wrong_seed_rejected():
    arr = np.arange(10, dtype=np.int32)
    frame = framing.build_frame(arr, seed=1, seq=0)
    with pytest.raises(framing.FrameError, match="seed"):
        framing.parse_frame(frame, seed=2)


def test_frame_tamper_rejected():
    arr = np.arange(300, dtype=np.float32)
    frame = framing.build_frame(arr, seed=1, seq=0)
    frame[2, 5] ^= 1
    with pytest.raises(framing.FrameError, match="MAC"):
        framing.parse_frame(frame, seed=1)


def test_frame_seq_rejected():
    arr = np.arange(4, dtype=np.int32)
    frame = framing.build_frame(arr, seed=1, seq=7)
    with pytest.raises(framing.FrameError, match="sequence"):
        framing.parse_frame(frame, seed=1, expect_seq=8)


def test_fast_mac_equals_reference():
    rng = np.random.default_rng(1)
    for rows in (1, 2, 63, 64, 65, 513):
        p = rng.integers(0, 2 ** 32, (rows, 128), dtype=np.uint64).astype(np.uint32)
        assert fast_mac(p, 123, block_rows=64) == framing._mac_np(p, 123)


# -- signatures / CA -----------------------------------------------------------

def test_sign_verify():
    kp = sig.KeyPair.generate("svc")
    s = sig.sign(kp.private, b"hello")
    assert sig.verify(kp.public, b"hello", s)
    assert not sig.verify(kp.public, b"tampered", s)
    other = sig.KeyPair.generate("other")
    assert not sig.verify(other.public, b"hello", s)


def test_dh_session_symmetry():
    a = sig.KeyPair.generate("a")
    b = sig.KeyPair.generate("b")
    assert sig.session_key(a.private, b.public) == sig.session_key(b.private, a.public)


def test_ca_grant_flow():
    ca = CertificateAuthority()
    enroll(ca, "svc-a")
    enroll(ca, "svc-b")
    dom, ka, kb = ca.grant_channel("svc-a", "svc-b")
    ca.registry.check(ka, RW)
    ca.registry.check(kb, RW)


def test_ca_rejects_unregistered_and_revoked():
    ca = CertificateAuthority()
    enroll(ca, "svc-a")
    with pytest.raises(AccessViolation):
        ca.grant_channel("svc-a", "ghost")
    enroll(ca, "svc-b")
    ca.revoke_service("svc-b")
    with pytest.raises(AccessViolation):
        ca.grant_channel("svc-a", "svc-b")


def test_ca_rejects_bad_proof():
    ca = CertificateAuthority()
    kp = sig.KeyPair.generate("mallory")
    bad_proof = sig.sign(kp.private, b"not the registration message")
    with pytest.raises(AccessViolation):
        ca.register("mallory", kp.public, bad_proof)
