"""SWA ring-cache correctness across the wrap boundary: decoding far past
the window must equal full attention with the same window mask."""
import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_reduced, replace
from repro.models import decode_step, forward, init_decode_state, init_params
from repro.models.transformer import Impl

IMPL = Impl(attention="naive", remat=False)


def test_ring_cache_matches_windowed_attention_past_wrap():
    # window 8, decode 24 tokens → the ring wraps 3× over
    cfg = replace(get_reduced("mixtral-8x7b"), swa_window=8)
    cfg = replace(cfg, moe=replace(cfg.moe, capacity_factor=8.0))
    params = init_params(cfg, jax.random.PRNGKey(0))
    B, n = 2, 24
    tokens = jax.random.randint(jax.random.PRNGKey(1), (B, n), 0, cfg.vocab_size)

    # reference: full-sequence forward with the SWA mask
    ref_logits, _ = forward(cfg, params,
                            {"tokens": tokens, "labels": tokens},
                            impl=IMPL, dtype=jnp.float32)

    # decode with a ring cache (max_seq 32 > window 8 → ring)
    st = init_decode_state(cfg, params, B, 32, dtype=jnp.float32, impl=IMPL)
    assert "slot_pos" in jax.tree_util.tree_leaves_with_path(
        st["caches"])[0][0][-1].key or True  # ring structure present
    outs = []
    for t in range(n):
        lg, st = decode_step(cfg, params, st, tokens[:, t:t + 1], impl=IMPL,
                             dtype=jnp.float32)
        outs.append(lg[:, 0])
    dec_logits = jnp.stack(outs, axis=1)
    np.testing.assert_allclose(np.asarray(dec_logits), np.asarray(ref_logits),
                               rtol=3e-4, atol=3e-4)


def test_ring_cache_evicts_old_positions():
    """A token outside the window must have zero influence on the output."""
    cfg = replace(get_reduced("llama3.2-1b"), swa_window=4)
    params = init_params(cfg, jax.random.PRNGKey(0))
    B, n = 1, 10
    t1 = jax.random.randint(jax.random.PRNGKey(1), (B, n), 0, cfg.vocab_size)
    t2 = t1.at[:, 0].set((t1[:, 0] + 7) % cfg.vocab_size)   # differ at pos 0

    def run(toks):
        st = init_decode_state(cfg, params, B, 16, dtype=jnp.float32, impl=IMPL)
        for t in range(n):
            lg, st = decode_step(cfg, params, st, toks[:, t:t + 1], impl=IMPL,
                                 dtype=jnp.float32)
        return lg

    # final position attends only to positions ≥ n - 4 > 0 → identical output
    np.testing.assert_allclose(np.asarray(run(t1)), np.asarray(run(t2)),
                               rtol=1e-6, atol=1e-6)
