"""Per-arch smoke tests (reduced configs): one forward/train step on CPU,
output shapes + finiteness + grads; decode-vs-forward consistency."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCH_IDS, get_reduced
from repro.models import (decode_step, encode, forward, init_decode_state,
                          init_params, loss_fn)
from repro.models.transformer import Impl

IMPL = Impl(attention="chunked", ssd="chunked", q_chunk=16, kv_chunk=16,
            remat=True)
B, S = 2, 32


def _batch(cfg, key):
    k1, k2 = jax.random.split(key)
    batch = {"tokens": jax.random.randint(k1, (B, S), 0, cfg.vocab_size),
             "labels": jax.random.randint(k2, (B, S), 0, cfg.vocab_size)}
    if cfg.vision_tokens:
        batch["vision_embeds"] = jnp.full(
            (B, cfg.vision_tokens, cfg.vision_dim), 0.1, jnp.float32)
        batch["labels"] = batch["labels"].at[:, :cfg.vision_tokens].set(-1)
    if cfg.enc_dec:
        batch["frames"] = jnp.full((B, cfg.enc_ctx, cfg.d_model), 0.1, jnp.float32)
    return batch


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_forward_and_grad(arch):
    cfg = get_reduced(arch)
    params = init_params(cfg, jax.random.PRNGKey(0))
    batch = _batch(cfg, jax.random.PRNGKey(1))
    logits, aux = forward(cfg, params, batch, impl=IMPL, dtype=jnp.float32)
    from repro.models.layers import padded_vocab
    assert logits.shape == (B, S, padded_vocab(cfg.vocab_size))
    assert np.isfinite(np.asarray(logits[..., :cfg.vocab_size])).all()
    (loss, metrics), grads = jax.value_and_grad(
        lambda p: loss_fn(cfg, p, batch, impl=IMPL, dtype=jnp.float32),
        has_aux=True)(params)
    assert np.isfinite(float(loss))
    gn = sum(float(jnp.sum(g.astype(jnp.float32) ** 2))
             for g in jax.tree.leaves(grads))
    assert np.isfinite(gn) and gn > 0


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_decode_runs(arch):
    cfg = get_reduced(arch)
    params = init_params(cfg, jax.random.PRNGKey(0))
    batch = _batch(cfg, jax.random.PRNGKey(1))
    enc_out = (encode(cfg, params, batch["frames"], impl=IMPL)
               if cfg.enc_dec else None)
    st = init_decode_state(cfg, params, B, 64, dtype=jnp.float32, impl=IMPL,
                           enc_out=enc_out)
    tok = batch["tokens"][:, :1]
    for _ in range(3):
        lg, st = decode_step(cfg, params, st, tok, impl=IMPL, dtype=jnp.float32)
        tok = jnp.argmax(lg[:, -1:, :cfg.vocab_size], -1).astype(jnp.int32)
    assert np.isfinite(np.asarray(lg[..., :cfg.vocab_size])).all()


# The strongest correctness check: teacher-forced incremental decode must
# reproduce the full-sequence forward logits for every family with a cache.
@pytest.mark.parametrize("arch", ["llama3.2-1b", "mamba2-1.3b", "zamba2-2.7b",
                                  "whisper-tiny", "mixtral-8x7b",
                                  "llava-next-mistral-7b"])
def test_decode_matches_forward(arch):
    cfg = get_reduced(arch)
    if cfg.moe:
        # capacity-based MoE drops depend on how many tokens route together;
        # loosen capacity so neither path drops and the functions must agree
        from repro.configs import replace
        cfg = replace(cfg, moe=replace(cfg.moe, capacity_factor=8.0))
    impl = Impl(attention="naive", ssd="chunked", remat=False)
    params = init_params(cfg, jax.random.PRNGKey(0))
    n = 12
    batch = _batch(cfg, jax.random.PRNGKey(1))
    tokens = batch["tokens"][:, :n]
    fwd_batch = dict(batch, tokens=tokens,
                     labels=batch["labels"][:, :n])
    if cfg.vision_tokens:
        # decode path has no vision prefix; compare pure-text
        fwd_batch.pop("vision_embeds")
    ref_logits, _ = forward(cfg, params, fwd_batch, impl=impl, dtype=jnp.float32)

    enc_out = (encode(cfg, params, batch["frames"].astype(jnp.float32), impl=impl)
               if cfg.enc_dec else None)
    st = init_decode_state(cfg, params, B, n + 4, dtype=jnp.float32, impl=impl,
                           enc_out=enc_out)
    outs = []
    for t in range(n):
        lg, st = decode_step(cfg, params, st, tokens[:, t:t + 1], impl=impl,
                             dtype=jnp.float32)
        outs.append(lg[:, 0])
    dec_logits = jnp.stack(outs, axis=1)
    np.testing.assert_allclose(dec_logits, ref_logits, rtol=2e-4, atol=2e-4)


def test_vlm_vision_prefix_changes_output():
    cfg = get_reduced("llava-next-mistral-7b")
    params = init_params(cfg, jax.random.PRNGKey(0))
    batch = _batch(cfg, jax.random.PRNGKey(1))
    l1, _ = forward(cfg, params, batch, impl=IMPL, dtype=jnp.float32)
    batch2 = dict(batch)
    batch2["vision_embeds"] = batch["vision_embeds"] * 2.0
    l2, _ = forward(cfg, params, batch2, impl=IMPL, dtype=jnp.float32)
    assert not np.allclose(np.asarray(l1), np.asarray(l2))


def test_loss_masks_labels():
    cfg = get_reduced("llama3.2-1b")
    params = init_params(cfg, jax.random.PRNGKey(0))
    batch = _batch(cfg, jax.random.PRNGKey(1))
    l_all, _ = loss_fn(cfg, params, batch, impl=IMPL, dtype=jnp.float32)
    batch_masked = dict(batch, labels=batch["labels"].at[:, :].set(-1))
    l_masked, _ = loss_fn(cfg, params, batch_masked, impl=IMPL, dtype=jnp.float32)
    assert float(l_masked) == 0.0
    assert float(l_all) > 0.0
