"""Docs can't rot: link integrity + structural checks for docs/ + README.

The CI docs job runs this module and then executes the README quickstart
commands (--quick variants); here we keep the cheap, hermetic half:
every relative link resolves, every doc the README promises exists, and
the protocol spec stays in sync with the constants it normatively
describes.
"""
import re
from pathlib import Path

import pytest

ROOT = Path(__file__).resolve().parent.parent
DOCS = sorted((ROOT / "docs").glob("*.md"))
PAGES = [ROOT / "README.md", *DOCS]

_LINK = re.compile(r"\[[^\]]+\]\(([^)#\s]+)(#[^)\s]*)?\)")


def test_docs_tree_exists():
    names = {p.name for p in DOCS}
    assert {"protocol.md", "architecture.md", "benchmarks.md"} <= names


@pytest.mark.parametrize("page", PAGES, ids=lambda p: p.name)
def test_relative_links_resolve(page):
    """Every non-URL link target in README/docs points at a real file."""
    broken = []
    for m in _LINK.finditer(page.read_text()):
        target = m.group(1)
        if target.startswith(("http://", "https://", "mailto:")):
            continue
        if not (page.parent / target).exists():
            broken.append(target)
    assert not broken, f"{page.name}: broken links {broken}"


def test_readme_links_every_doc():
    readme = (ROOT / "README.md").read_text()
    for doc in ("docs/protocol.md", "docs/architecture.md",
                "docs/benchmarks.md"):
        assert doc in readme, f"README does not link {doc}"


def test_protocol_spec_matches_code_constants():
    """The normative spec quotes magics/constants — keep them honest.

    The hand-maintained constant list that used to live here moved into
    the analyzer (MPK201, rules_spec.py): the rule harvests the constants
    straight from the defining modules, so this test can't silently rot
    when a new magic is added."""
    from repro.analysis import analyze_paths
    from repro.analysis.rules_spec import SpecConstantSyncRule

    report = analyze_paths(
        [ROOT / "src" / "repro" / "core", ROOT / "src" / "repro" / "kernels"],
        rules=[SpecConstantSyncRule()], root=ROOT)
    assert [f.render() for f in report.findings if not f.suppressed] == []


def test_protocol_taxonomy_covers_every_typed_error():
    """Every typed error the code can raise to a client must appear in
    the protocol table. The error-name list that used to be duplicated
    here is now derived by MPK202 from the TransportError class tree."""
    from repro.analysis import analyze_paths
    from repro.analysis.rules_spec import SpecTaxonomySyncRule

    report = analyze_paths([ROOT / "src" / "repro" / "core"],
                           rules=[SpecTaxonomySyncRule()], root=ROOT)
    assert [f.render() for f in report.findings if not f.suppressed] == []
    # and the README still defers to the spec instead of duplicating it
    readme = (ROOT / "README.md").read_text()
    assert "docs/protocol.md" in readme


def test_committed_benchmark_jsons_match_docs_claims():
    """docs/benchmarks.md describes the committed JSONs — the gates it
    cites must actually hold in the committed artifacts."""
    import json

    gw = json.loads((ROOT / "benchmarks" / "results"
                     / "gateway_bench.json").read_text())
    assert gw["all_macs_verified"] is True
    assert gw.get("batch_gate_mpklink_opt_2x") is True
    assert gw["batch_speedup_16_over_lockstep"]["mpklink_opt/wordcount"] >= 2.0
    # PR 4 gates: zero-copy seal path + sharded scatter executor
    assert gw.get("zero_copy_gate_mpklink_opt_1p5x") is True
    assert gw.get("scatter_gate_workers4_2x") is True
    assert gw["scatter_speedup_vs_sequential"]["workers4"] >= 2.0
    # PR 5 gates: adaptive coalescing at high fan-in
    assert gw.get("coalesce_gate_mpklink_opt_64c_2x") is True
    assert gw.get("coalesce_wakeup_gate_4x") is True
    fi = gw["fanin_speedup_coalesced_over_inline"]
    assert fi["mpklink_opt/64c"] >= 2.0
    assert fi["mpklink_opt/64c_wakeup_reduction"] >= 4.0
    for cell in gw["fanin_results"]:
        assert cell["all_macs_verified"] is True, cell["mode"]
    zc_k4 = [v for k, v in gw["zero_copy_speedup"].items()
             if k.startswith("mpklink_opt/") and k.endswith("/k4")]
    assert zc_k4 and min(zc_k4) >= 1.5
    # the zero-copy cells really are concat-free on the request path
    for cell in gw["payload_results"]:
        if cell["mode"] == "zero_copy":
            assert cell["concat_calls_per_request"] == 0, cell
            assert cell["bytes_copied_per_request"] \
                < 1.2 * cell["payload_bytes"] + 4096, cell
    chaos = json.loads((ROOT / "benchmarks" / "results"
                        / "chaos_bench.json").read_text())
    gates = chaos["gates"]
    assert gates["mpklink_opt_10pct_sustains_half"] is not False
    # PR 8 gates: replica-fleet scaling + kill -9 chaos cell
    fleet = json.loads((ROOT / "benchmarks" / "results"
                        / "fleet_bench.json").read_text())
    fgates = fleet["gates"]
    for g in ("all_answers_correct", "no_lost_requests",
              "kill_cell_zero_lost", "kill_victim_marked_dead",
              "fleet_4r_2x_1r_poisson"):
        assert fgates[g] is True, g
    assert fgates["fleet_4r_vs_1r_rps_ratio_poisson"] >= 2.0
