"""Chaos conformance suite: the fault-injection fabric vs all six transports.

Every test drives a gateway through a seeded :class:`FaultPlan` and asserts
the three contract clauses:

  (a) no client ever hangs — every run finishes inside an explicit
      wall-clock budget (transports all have bounded response waits now);
  (b) every injected security fault surfaces as the CORRECT typed
      exception (FrameError vs AccessViolation vs ServiceCrashed vs
      ResponseTimeout — see faultwire.EXPECTED), enforced inside
      FaultyClient (a mis-typed or accepted fault raises FaultLeak);
  (c) an identical seed produces the identical fault schedule AND the
      identical outcome sequence.

On failure, the printed ``FaultPlan.from_spec(...)`` line replays the run.
"""
import time

import numpy as np
import pytest

from repro.core import PROC_TRANSPORTS, TRANSPORTS, ServiceGateway
from repro.core.faultwire import (ALL_KINDS, EXPECTED, FaultFabric, FaultPlan,
                                  FaultyClient)
from repro.core.transports import (HandlerCrash, MPKLinkOptTransport,
                                   ResponseTimeout, ServiceCrashed,
                                   ShmTransport)
from repro.core.wordcount import make_text, parse_count, wordcount_handler

TIMEOUT = 0.4                      # transport response deadline under chaos
WALL_BUDGET = 60.0                 # hard per-run bound: nothing may hang


def _chaos_gateway(transport: str) -> ServiceGateway:
    gw = ServiceGateway(transport, transport_kwargs={"timeout": TIMEOUT})
    gw.register_service("wordcount", wordcount_handler,
                        factory=lambda: wordcount_handler)
    return gw.start()


def _run(transport: str, plan: FaultPlan, *, retries: int = 0):
    """→ (outcome signature list, wall seconds). The signature is the
    deterministic fingerprint used by the replay test."""
    gw = _chaos_gateway(transport)
    fab = FaultFabric(plan).attach(gw)
    fc = FaultyClient(gw.connect("chaos-client", retries=retries), fab,
                      "wordcount")
    t0 = time.perf_counter()
    try:
        for i in range(plan.n_requests):
            n = 4 + i % 9
            out = fc.step(make_text(n, seed=i))
            if out.status == "ok":
                assert parse_count(out.value) == n, \
                    f"wrong answer at request {i} — replay: {plan.describe()}"
    finally:
        wall = time.perf_counter() - t0
        gw.close()
    sig = [(o.index, o.status, o.kind, type(o.value).__name__)
           for o in fc.outcomes]
    return sig, wall, fc


@pytest.mark.parametrize("name", sorted(TRANSPORTS))
def test_chaos_all_kinds_bounded_and_typed(name):
    """(a)+(b): full-kind plan on every transport — bounded wall clock,
    correct types (typing is enforced by FaultyClient: anything off raises
    FaultLeak), and zero collateral failures on non-faulted requests."""
    plan = FaultPlan(seed=2024, n_requests=40, rate=0.25)
    sig, wall, fc = _run(name, plan)
    assert wall < WALL_BUDGET, f"hung? {wall}s — replay: {plan.describe()}"
    counts = fc.counts()
    assert counts["error"] == 0, \
        (f"non-faulted request failed: "
         f"{[s for s in sig if s[1] == 'error']} — replay: {plan.describe()}")
    assert counts["fault"] + counts["recovered"] == len(plan.events)
    # every fault kind that fired surfaced as its EXPECTED type
    for o in fc.outcomes:
        if o.status == "fault":
            assert isinstance(o.value, EXPECTED[o.kind]), \
                f"{o} — replay: {plan.describe()}"


@pytest.mark.parametrize("name", sorted(TRANSPORTS))
@pytest.mark.parametrize("kind", ALL_KINDS)
def test_chaos_single_kind(name, kind):
    """(b) per cell: one fault kind × one transport, ≥2 injections."""
    plan = FaultPlan(seed=hash((name, kind)) & 0xFFFF, n_requests=12,
                     rate=0.25, kinds=(kind,))
    assert len(plan.events) >= 2
    sig, wall, fc = _run(name, plan)
    assert wall < WALL_BUDGET, f"hung? — replay: {plan.describe()}"
    assert fc.counts()["error"] == 0, f"replay: {plan.describe()}"
    expected = EXPECTED[kind]
    for o in fc.outcomes:
        if o.kind != kind:
            continue
        if expected is None:                       # delay: must complete
            assert o.ok, f"{o} — replay: {plan.describe()}"
        elif o.status == "fault":
            assert isinstance(o.value, expected), \
                f"{o} — replay: {plan.describe()}"


@pytest.mark.parametrize("name", ["mpklink_opt", "pipe", "shm"])
def test_chaos_identical_seed_identical_outcomes(name):
    """(c): the fault schedule AND the outcome sequence are pure functions
    of (seed, plan) — two full runs fingerprint identically."""
    spec = FaultPlan(seed=777, n_requests=30, rate=0.3).spec()
    p1, p2 = FaultPlan.from_spec(spec), FaultPlan.from_spec(spec)
    assert [e for e in p1.schedule()] == [e for e in p2.schedule()]
    sig1, _, _ = _run(name, p1)
    sig2, _, _ = _run(name, p2)
    assert sig1 == sig2, f"nondeterministic — replay: {p1.describe()}"


def test_chaos_retries_heal_liveness_faults():
    """With bounded retries + idempotency tokens, liveness faults (crash/
    drop) are transparently healed: the answer is still correct and the
    handler is never double-executed for an already-completed request."""
    calls = []

    def counting(req):
        calls.append(1)
        return wordcount_handler(req)

    gw = ServiceGateway("mpklink_opt", transport_kwargs={"timeout": TIMEOUT})
    gw.register_service("wordcount", counting, factory=lambda: counting)
    gw.start()
    plan = FaultPlan(seed=5, n_requests=20, rate=0.3,
                     kinds=("drop_response", "crash_handler"))
    fab = FaultFabric(plan).attach(gw)
    fc = FaultyClient(gw.connect("healer", retries=3), fab, "wordcount")
    try:
        for i in range(plan.n_requests):
            n = 5 + i % 4
            out = fc.step(make_text(n, seed=i))
            assert out.ok, f"{out} — replay: {plan.describe()}"
            assert parse_count(out.value) == n
    finally:
        gw.close()
    n_drops = sum(1 for e in plan.events.values()
                  if e.kind == "drop_response")
    # dropped responses were answered from the dedup window on retry —
    # executed exactly once; only crashes (pre-execution kills) re-execute
    assert gw.stats["deduped"] == n_drops
    assert len(calls) == plan.n_requests


# ---------------------------------------------------------------------------
# process-backed transports: the crash fault is now a REAL kill -9 of the
# service process (docs/protocol.md §6) — same contract clauses (a)/(b)/(c).
# Assertions are client-observable only: server-side fabric state (`fired`)
# lives in the forked child and dies with it.
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("name", sorted(PROC_TRANSPORTS))
def test_chaos_proc_all_kinds_bounded_and_typed(name):
    """Full-kind plan against a real multiprocessing service: every fault
    typed, every wait bounded, zero collateral failures — with crash
    faults killing (and heals re-forking) actual OS processes."""
    plan = FaultPlan(seed=2024, n_requests=40, rate=0.25)
    sig, wall, fc = _run(name, plan)
    assert wall < WALL_BUDGET, f"hung? {wall}s — replay: {plan.describe()}"
    counts = fc.counts()
    assert counts["error"] == 0, \
        (f"non-faulted request failed: "
         f"{[s for s in sig if s[1] == 'error']} — replay: {plan.describe()}")
    for o in fc.outcomes:
        if o.status == "fault":
            assert isinstance(o.value, EXPECTED[o.kind]), \
                f"{o} — replay: {plan.describe()}"


@pytest.mark.parametrize("name", sorted(PROC_TRANSPORTS))
@pytest.mark.parametrize("kind", ALL_KINDS)
def test_chaos_proc_single_kind(name, kind):
    """8 fault kinds × 3 process-backed transports, ≥2 injections each,
    replayable from (seed, plan)."""
    plan = FaultPlan(seed=hash((name, kind)) & 0xFFFF, n_requests=12,
                     rate=0.25, kinds=(kind,))
    assert len(plan.events) >= 2
    sig, wall, fc = _run(name, plan)
    assert wall < WALL_BUDGET, f"hung? — replay: {plan.describe()}"
    assert fc.counts()["error"] == 0, f"replay: {plan.describe()}"
    expected = EXPECTED[kind]
    for o in fc.outcomes:
        if o.kind != kind:
            continue
        if expected is None:                       # delay: must complete
            assert o.ok, f"{o} — replay: {plan.describe()}"
        elif o.status == "fault":
            assert isinstance(o.value, expected), \
                f"{o} — replay: {plan.describe()}"


@pytest.mark.parametrize("name", ["mpklink_opt_proc", "shm_proc"])
def test_chaos_proc_identical_seed_identical_outcomes(name):
    """(c) across process boundaries: the shared-memory fault index keeps
    the schedule monotonic across forks and heals, so two full runs still
    fingerprint identically."""
    spec = FaultPlan(seed=777, n_requests=30, rate=0.3).spec()
    p1, p2 = FaultPlan.from_spec(spec), FaultPlan.from_spec(spec)
    sig1, _, _ = _run(name, p1)
    sig2, _, _ = _run(name, p2)
    assert sig1 == sig2, f"nondeterministic — replay: {p1.describe()}"


def test_chaos_proc_crash_is_a_real_sigkill():
    """The crash fault kind must actually kill -9 the service process —
    not just raise in a thread. Verified via the dead child's exitcode."""
    import signal as _signal

    gw = _chaos_gateway("mpklink_opt_proc")
    sessions = []
    orig_connect = gw.transport.connect

    def tracking_connect(*a, **kw):
        s = orig_connect(*a, **kw)
        sessions.append(s)
        return s

    gw.transport.connect = tracking_connect
    plan = FaultPlan(seed=9, n_requests=8, rate=0.5,
                     kinds=("crash_handler",))
    assert len(plan.events) >= 2
    fab = FaultFabric(plan).attach(gw)
    fc = FaultyClient(gw.connect("chaos-client"), fab, "wordcount")
    try:
        for i in range(plan.n_requests):
            n = 4 + i % 9
            out = fc.step(make_text(n, seed=i))
            if out.status == "fault":
                assert isinstance(out.value, ServiceCrashed), \
                    f"{out} — replay: {plan.describe()}"
    finally:
        gw.close()
    kills = [s for s in sessions
             if s._proc is not None and s._proc.exitcode == -_signal.SIGKILL]
    assert len(kills) >= 2, \
        (f"crash faults fired but no service process died by SIGKILL "
         f"— replay: {plan.describe()}")


# ---------------------------------------------------------------------------
# satellite: "handler died" is typed, immediate — never a deadline stall
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("cls", [ShmTransport, MPKLinkOptTransport])
def test_session_crash_is_typed_and_immediate(cls):
    """A service thread that dies mid-request must surface ServiceCrashed
    at once — the client must NOT wait out the (long) response deadline."""
    def die(req):
        raise HandlerCrash("boom")

    tr = cls(die, timeout=30.0)
    tr.start()
    try:
        t0 = time.perf_counter()
        with pytest.raises(ServiceCrashed):
            tr.request(np.arange(4, dtype=np.uint8))
        elapsed = time.perf_counter() - t0
        assert elapsed < 5.0, f"sat out the deadline: {elapsed}s"
        # the dead session is refused immediately too (no new deadline wait)
        t0 = time.perf_counter()
        with pytest.raises(ServiceCrashed):
            tr._sessions[0].request(np.arange(4, dtype=np.uint8))
        assert time.perf_counter() - t0 < 1.0
        # ...and the transport-level API transparently reconnects; the new
        # session crashes again (same handler) but stays typed and fast
        with pytest.raises(ServiceCrashed):
            tr.request(np.arange(4, dtype=np.uint8))
    finally:
        tr.close()


def test_pipe_send_side_is_deadline_bounded():
    """A wedged service thread stops draining the request pipe; a large
    send must hit the deadline (typed), not block forever in os.write."""
    import threading

    gate = threading.Event()

    def wedged(req):
        gate.wait(10)                   # stuck handler: pipe not drained
        return np.asarray(req)

    tr = TRANSPORTS["pipe"](wedged, timeout=0.3)
    tr.start()
    s = tr.connect("w")
    try:
        first_err = []

        def occupy():                   # park the service thread in wedged()
            try:
                s.request(np.zeros(8, np.uint8))
            except Exception as e:
                first_err.append(e)

        t = threading.Thread(target=occupy, daemon=True)
        t.start()
        time.sleep(0.05)
        t0 = time.perf_counter()
        with pytest.raises(ResponseTimeout):
            # 1 MiB ≫ the pipe buffer: the send itself must be bounded
            s.request(np.zeros(1 << 20, np.uint8))
        assert time.perf_counter() - t0 < 5.0
    finally:
        gate.set()
        tr.close()


def test_timeout_vs_crash_are_distinct_types():
    """A slow handler is a ResponseTimeout; a dead handler is a
    ServiceCrashed — retry layers treat them differently."""
    def slow(req):
        time.sleep(0.5)
        return np.asarray(req)

    tr = ShmTransport(slow, timeout=0.05)
    tr.start()
    try:
        with pytest.raises(ResponseTimeout):
            tr.request(np.arange(4, dtype=np.uint8))
    finally:
        tr.close()


# ---------------------------------------------------------------------------
# EngineService: a killed engine worker recovers mid-decode
# ---------------------------------------------------------------------------

def test_engine_service_recovers_from_midflight_crash():
    import jax
    from repro.configs import get_reduced
    from repro.models import init_params
    from repro.models.transformer import Impl
    from repro.runtime import EngineService, ServingEngine, encode_prompt

    cfg = get_reduced("llama3.2-1b")
    params = init_params(cfg, jax.random.PRNGKey(0))
    engine = ServingEngine(cfg, params, max_batch=2, max_seq=32,
                           impl=Impl(attention="naive", remat=False))
    svc = EngineService(engine, timeout=60.0).start()
    gw = ServiceGateway("mpklink_opt", transport_kwargs={"timeout": 60.0})
    gw.register_service("infer", svc.handler)
    gw.start()
    try:
        c = gw.connect("driver", retries=2)
        out = c.call("infer", encode_prompt([1, 2, 3], max_new=4))
        assert np.asarray(out).size == 4

        # kill the engine worker mid-decode: the in-flight request fails
        # typed + immediately, and the retrying client transparently
        # resubmits on the healed engine
        svc.inject_crash()
        out = c.call("infer", encode_prompt([4, 5], max_new=3))
        assert np.asarray(out).size == 3
        assert svc.crashes >= 1
        # engine keeps serving new work after the crash
        out = c.call("infer", encode_prompt([7], max_new=2))
        assert np.asarray(out).size == 2
    finally:
        gw.close()
        svc.close()

    # crash-recovery delivery semantics (unit, on an un-started service
    # sharing the same engine): work the dying tick already retired is
    # DELIVERED; queued/slotted work fails typed — nobody is stranded
    import threading
    from repro.runtime import Request
    from repro.runtime.serve import EngineService as ES

    svc2 = ES(engine, timeout=5.0)
    finished = Request(rid=1, prompt=[1])
    finished.generated = [42]
    doomed = Request(rid=2, prompt=[2])
    ev1, ev2 = threading.Event(), threading.Event()
    svc2._events = {1: ev1, 2: ev2}
    engine.completed.append(finished)
    engine.queue.append(doomed)
    svc2._recover(RuntimeError("boom"))
    assert ev1.is_set() and ev2.is_set()
    assert svc2._done[1] is finished               # delivered, not dropped
    assert isinstance(svc2._failed[2], ServiceCrashed)
    assert svc2.crashes == 1 and engine.queue == []


# ---------------------------------------------------------------------------
# supervisor wiring: gateway health → heartbeat view → recovery plan
# ---------------------------------------------------------------------------

def test_gateway_supervisor_restarts_open_circuits():
    from repro.runtime import GatewaySupervisor, plan_gateway_recovery

    healthy = {"a": {"state": "closed"}, "b": {"state": "open"},
               "c": {"state": "open"}, "d": {"state": "half_open"}}
    assert plan_gateway_recovery(healthy, {"b"}) == \
        [("restart", "b"), ("shed", "c"), ("probe", "d")]

    boom = {"n": 0}

    def flaky(req):
        boom["n"] += 1
        if boom["n"] <= 3:
            raise ValueError("flaky")
        return wordcount_handler(req)

    gw = ServiceGateway("uds")
    # no factory → the breaker opens instead of self-restarting inline;
    # the supervisor sweep is what heals it
    gw.register_service("wc", flaky, failure_threshold=3, probe_after=100)
    gw.start()
    sup = GatewaySupervisor(gw)
    try:
        c = gw.connect("x")
        for i in range(3):
            with pytest.raises(Exception):
                c.call("wc", make_text(4, seed=i))
        assert gw.health()["wc"]["state"] == "open"
        assert sup.observe()["wc"]["state"] == "open"
        assert "wc" not in sup.monitor.alive()
        gw._services["wc"].factory = lambda: flaky     # operator intervenes
        assert sup.heal() == [("restart", "wc")]
        assert gw.health()["wc"]["state"] == "closed"
        # epoch was bumped by the restart: the client re-keys transparently
        assert parse_count(c.call("wc", make_text(9, seed=9))) == 9
        assert "wc" in sup.monitor.alive() or sup.observe()["wc"]["state"] == "closed"
    finally:
        gw.close()
