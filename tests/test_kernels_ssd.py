"""Mamba2 SSD: chunked jnp twin and Pallas kernel vs the sequential oracle."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels.ref import ssd_ref
from repro.kernels.ssd_jnp import ssd_chunked, ssd_decode_step
from repro.kernels.ssd_scan import ssd_scan_pallas

CASES = [
    # B, S, H, P, G, N, chunk
    (2, 37, 4, 8, 1, 16, 8),
    (1, 64, 6, 4, 2, 8, 16),
    (2, 16, 2, 4, 2, 4, 16),
    (1, 5, 4, 8, 4, 8, 4),
]


def _inputs(case, seed=1, dtype=jnp.float32):
    B, S, H, P, G, N, Q = case
    ks = jax.random.split(jax.random.PRNGKey(seed), 6)
    x = jax.random.normal(ks[0], (B, S, H, P), dtype)
    dt = jax.nn.softplus(jax.random.normal(ks[1], (B, S, H))) * 0.1
    A_log = jax.random.normal(ks[2], (H,)) * 0.5
    Bm = jax.random.normal(ks[3], (B, S, G, N), dtype)
    Cm = jax.random.normal(ks[4], (B, S, G, N), dtype)
    D = jax.random.normal(ks[5], (H,))
    return x, dt, A_log, Bm, Cm, D, Q


@pytest.mark.parametrize("case", CASES)
def test_chunked_matches_ref(case):
    x, dt, A_log, Bm, Cm, D, Q = _inputs(case)
    yr, sr = ssd_ref(x, dt, A_log, Bm, Cm, D)
    yc, sc = ssd_chunked(x, dt, A_log, Bm, Cm, D, chunk=Q)
    np.testing.assert_allclose(yc, yr, rtol=1e-4, atol=1e-4)
    np.testing.assert_allclose(sc, sr, rtol=1e-4, atol=1e-4)


@pytest.mark.parametrize("case", CASES[:3])
def test_pallas_matches_ref(case):
    x, dt, A_log, Bm, Cm, D, Q = _inputs(case)
    S = x.shape[1]
    if S % Q:                                    # ops.py pads; test via ops
        from repro.kernels.ops import ssd
        yp, sp = ssd(x, dt, A_log, Bm, Cm, D, chunk=Q, impl="pallas")
    else:
        yp, sp = ssd_scan_pallas(x, dt, A_log, Bm, Cm, D, chunk=Q)
    yr, sr = ssd_ref(x, dt, A_log, Bm, Cm, D)
    np.testing.assert_allclose(yp, yr, rtol=1e-4, atol=1e-4)
    np.testing.assert_allclose(sp, sr, rtol=1e-4, atol=1e-4)


def test_state_continuation():
    """Splitting a sequence and carrying the state == processing it whole."""
    case = (2, 32, 4, 8, 1, 16, 8)
    x, dt, A_log, Bm, Cm, D, Q = _inputs(case)
    yr, sr = ssd_ref(x, dt, A_log, Bm, Cm, D)
    h = 16
    y1, s1 = ssd_chunked(x[:, :h], dt[:, :h], A_log, Bm[:, :h], Cm[:, :h], D, chunk=Q)
    y2, s2 = ssd_chunked(x[:, h:], dt[:, h:], A_log, Bm[:, h:], Cm[:, h:], D,
                         init_state=s1, chunk=Q)
    np.testing.assert_allclose(jnp.concatenate([y1, y2], 1), yr, rtol=1e-4, atol=1e-4)
    np.testing.assert_allclose(s2, sr, rtol=1e-4, atol=1e-4)


def test_decode_chain_matches_ref():
    case = (1, 12, 4, 8, 2, 8, 4)
    x, dt, A_log, Bm, Cm, D, Q = _inputs(case)
    yr, sr = ssd_ref(x, dt, A_log, Bm, Cm, D)
    B, S, H, P = x.shape
    st = jnp.zeros((B, H, P, Bm.shape[-1]))
    ys = []
    for t in range(S):
        y_t, st = ssd_decode_step(x[:, t], dt[:, t], A_log, Bm[:, t], Cm[:, t], D, st)
        ys.append(y_t)
    np.testing.assert_allclose(jnp.stack(ys, 1), yr, rtol=1e-4, atol=1e-4)
    np.testing.assert_allclose(st, sr, rtol=1e-4, atol=1e-4)


def test_grads_finite():
    case = (1, 16, 2, 4, 1, 8, 8)
    x, dt, A_log, Bm, Cm, D, Q = _inputs(case)
    g = jax.grad(lambda x: ssd_chunked(x, dt, A_log, Bm, Cm, D, chunk=Q)[0].sum())(x)
    assert np.isfinite(np.asarray(g)).all()
