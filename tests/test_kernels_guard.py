"""mpk_guard kernel: MAC correctness, tamper/tag/truncation detection."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels.mpk_guard import guard_copy_pallas
from repro.kernels.ops import guard_copy
from repro.kernels.ref import guard_copy_ref, mac_ref


def _payload(rows, seed=0):
    return jax.random.bits(jax.random.PRNGKey(seed), (rows, 128), dtype=jnp.uint32)


@pytest.mark.parametrize("rows,tile", [(4, 4), (8, 4), (256, 64), (32, 32), (7, 4)])
def test_guard_copy_roundtrip(rows, tile):
    p = _payload(rows)
    tag = jnp.uint32(42)
    mac = mac_ref(p, tag)
    out, macp, ok = guard_copy(p, tag, mac, rows_per_tile=tile)
    assert (out == p).all()
    assert int(macp[0]) == int(mac)
    assert int(ok[0]) == 1


def test_wrong_tag_rejected():
    p = _payload(16)
    mac = mac_ref(p, jnp.uint32(1))
    _, _, ok = guard_copy(p, jnp.uint32(2), mac)
    assert int(ok[0]) == 0


@pytest.mark.parametrize("row,lane", [(0, 0), (7, 127), (3, 64)])
def test_single_bit_tamper_rejected(row, lane):
    p = _payload(8, seed=3)
    tag = jnp.uint32(9)
    mac = mac_ref(p, tag)
    tampered = p.at[row, lane].set(p[row, lane] ^ jnp.uint32(1))
    _, _, ok = guard_copy(tampered, tag, mac, rows_per_tile=4)
    assert int(ok[0]) == 0


def test_ref_and_pallas_agree():
    p = _payload(64, seed=5)
    tag = jnp.uint32(77)
    mac = mac_ref(p, tag)
    outr, macr, okr = guard_copy_ref(p, tag, mac)
    outp, macp, okp = guard_copy_pallas(p, tag, mac, rows_per_tile=16)
    assert int(macr) == int(macp[0])
    assert int(okr) == int(okp[0]) == 1


def test_epoch_seed_changes_mac():
    """domains.mac_seed mixes the epoch — a revocation invalidates old MACs."""
    from repro.core.domains import KeyRegistry, mac_seed
    reg = KeyRegistry()
    dom = reg.allocate_domain("chan")
    key = reg.issue_key(dom)
    s0 = mac_seed(dom, reg.epoch(dom))
    reg.revoke(key)
    s1 = mac_seed(dom, reg.epoch(dom))
    assert s0 != s1
    p = _payload(4)
    assert int(mac_ref(p, jnp.uint32(s0))) != int(mac_ref(p, jnp.uint32(s1)))
