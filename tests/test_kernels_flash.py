"""Flash attention: jnp twin (fwd+bwd) and Pallas kernel (interpret) vs the
naive oracle, swept over shapes/dtypes/masking modes."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels.flash_attention import flash_attention_pallas
from repro.kernels.flash_jnp import flash_attention_jnp
from repro.kernels.ref import attention_ref

CASES = [
    # B, Sq, Skv, H, Hkv, Dh, causal, window, qc, kc
    (2, 17, 17, 4, 2, 8, True, None, 8, 8),
    (1, 33, 33, 6, 3, 16, True, 5, 8, 8),
    (2, 1, 40, 4, 2, 8, True, None, 8, 8),       # decode shape
    (2, 24, 24, 4, 4, 8, False, None, 8, 8),     # MHA, non-causal (cross-attn)
    (1, 64, 64, 2, 1, 32, True, 16, 16, 16),     # SWA
    (1, 9, 40, 3, 3, 8, True, None, 4, 16),      # ragged chunking
]


def _inputs(case, dtype=jnp.float32, seed=0):
    B, Sq, Skv, H, Hkv, Dh, causal, win, qc, kc = case
    ks = jax.random.split(jax.random.PRNGKey(seed), 4)
    q = jax.random.normal(ks[0], (B, Sq, H, Dh), dtype)
    k = jax.random.normal(ks[1], (B, Skv, Hkv, Dh), dtype)
    v = jax.random.normal(ks[2], (B, Skv, Hkv, Dh), dtype)
    qp = jnp.broadcast_to(jnp.arange(Skv - Sq, Skv, dtype=jnp.int32), (B, Sq))
    kp = jnp.broadcast_to(jnp.arange(Skv, dtype=jnp.int32), (B, Skv))
    if Skv > 8:
        kp = kp.at[:, -3:].set(-1)               # unfilled cache slots
    return q, k, v, qp, kp, causal, win, qc, kc


@pytest.mark.parametrize("case", CASES)
def test_jnp_twin_forward(case):
    q, k, v, qp, kp, causal, win, qc, kc = _inputs(case)
    ref = attention_ref(q, k, v, qp, kp, causal=causal, window=win)
    got = flash_attention_jnp(q, k, v, qp, kp, causal=causal, window=win,
                              q_chunk=qc, kv_chunk=kc)
    np.testing.assert_allclose(got, ref, rtol=2e-5, atol=2e-5)


@pytest.mark.parametrize("case", CASES[:4])
def test_jnp_twin_grads(case):
    q, k, v, qp, kp, causal, win, qc, kc = _inputs(case)

    def loss_ref(q, k, v):
        return (attention_ref(q, k, v, qp, kp, causal=causal, window=win) ** 2).sum()

    def loss_got(q, k, v):
        return (flash_attention_jnp(q, k, v, qp, kp, causal=causal, window=win,
                                    q_chunk=qc, kv_chunk=kc) ** 2).sum()

    gr = jax.grad(loss_ref, argnums=(0, 1, 2))(q, k, v)
    gg = jax.grad(loss_got, argnums=(0, 1, 2))(q, k, v)
    for a, b, name in zip(gr, gg, "qkv"):
        np.testing.assert_allclose(b, a, rtol=5e-5, atol=5e-5, err_msg=name)


@pytest.mark.parametrize("case", CASES)
def test_pallas_kernel(case):
    q, k, v, qp, kp, causal, win, qc, kc = _inputs(case)
    # kernel requires divisible shapes; ops.py pads — pad here like ops does
    from repro.kernels.ops import attention
    ref = attention_ref(q, k, v, qp, kp, causal=causal, window=win)
    got = attention(q, k, v, qp, kp, causal=causal, window=win, impl="pallas",
                    q_chunk=qc, kv_chunk=kc)
    np.testing.assert_allclose(got, ref, rtol=2e-5, atol=2e-5)


@pytest.mark.parametrize("dtype,tol", [(jnp.float32, 2e-5), (jnp.bfloat16, 2e-2)])
def test_dtypes(dtype, tol):
    case = (2, 32, 32, 4, 2, 16, True, None, 8, 8)
    q, k, v, qp, kp, causal, win, qc, kc = _inputs(case, dtype=dtype)
    ref = attention_ref(q, k, v, qp, kp, causal=causal, window=win)
    jn = flash_attention_jnp(q, k, v, qp, kp, causal=causal, q_chunk=qc, kv_chunk=kc)
    pa = flash_attention_pallas(q, k, v, qp, kp, causal=causal,
                                q_chunk=qc, kv_chunk=kc)
    np.testing.assert_allclose(jn.astype(jnp.float32), ref.astype(jnp.float32),
                               rtol=tol, atol=tol)
    np.testing.assert_allclose(pa.astype(jnp.float32), ref.astype(jnp.float32),
                               rtol=tol, atol=tol)


def test_fully_masked_rows_zero():
    """Queries with q_pos < 0 (padding) must produce exactly 0."""
    case = (1, 8, 8, 2, 2, 8, True, None, 4, 4)
    q, k, v, qp, kp, causal, win, qc, kc = _inputs(case)
    qp = qp.at[:, -2:].set(-2)
    out = flash_attention_jnp(q, k, v, qp, kp, causal=True, q_chunk=4, kv_chunk=4)
    assert np.abs(np.asarray(out[:, -2:])).max() == 0.0
