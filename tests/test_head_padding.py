"""Head padding (--opt-pad-heads) must be function-preserving: embedding the
real heads of an unpadded attention into the padded layout (zeros elsewhere)
produces bit-equal outputs."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_reduced, replace
from repro.models import attention as attn_mod
from repro.models import forward, init_params
from repro.models.transformer import Impl


def _embed_padded(cfg, cfg_pad, p0):
    """Place p0's real-head weights into a zeroed padded layout."""
    H, Hkv, Dh, D = cfg.num_heads, cfg.num_kv_heads, cfg.head_dim, cfg.d_model
    Hp, Hkvp = cfg_pad.q_heads_eff, cfg_pad.kv_heads_eff
    g, gp = H // Hkv, Hp // Hkvp
    wq = jnp.zeros((D, Hkvp, gp, Dh)).at[:, :Hkv, :g].set(
        p0["wq"].reshape(D, Hkv, g, Dh)).reshape(D, Hp, Dh)
    wo = jnp.zeros((Hkvp, gp, Dh, D)).at[:Hkv, :g].set(
        p0["wo"].reshape(Hkv, g, Dh, D)).reshape(Hp, Dh, D)
    wk = jnp.zeros((D, Hkvp, Dh)).at[:, :Hkv].set(p0["wk"])
    wv = jnp.zeros((D, Hkvp, Dh)).at[:, :Hkv].set(p0["wv"])
    p1 = {"wq": wq, "wk": wk, "wv": wv, "wo": wo}
    for k in ("q_norm", "k_norm"):
        if k in p0:
            p1[k] = p0[k]
    return p1


@pytest.mark.parametrize("arch,pads", [
    ("qwen3-14b", dict(pad_q_heads=8, pad_kv_heads=4)),      # reduced: 4H/2KV
    ("smollm-360m", dict(pad_q_heads=8, pad_kv_heads=2)),    # reduced: 3H/1KV
])
@pytest.mark.parametrize("impl_name", ["naive", "chunked"])
def test_padding_preserves_attention(arch, pads, impl_name):
    cfg = get_reduced(arch)
    cfg_pad = replace(cfg, **pads)
    key = jax.random.PRNGKey(0)
    p0 = attn_mod.init_attn(cfg, key)
    p1 = _embed_padded(cfg, cfg_pad, p0)

    x = jax.random.normal(jax.random.PRNGKey(1), (2, 16, cfg.d_model))
    pos = jnp.broadcast_to(jnp.arange(16, dtype=jnp.int32)[None], (2, 16))
    y0 = attn_mod.apply_attn(cfg, p0, x, positions=pos, impl=impl_name,
                             q_chunk=8, kv_chunk=8)
    y1 = attn_mod.apply_attn(cfg_pad, p1, x, positions=pos, impl=impl_name,
                             q_chunk=8, kv_chunk=8)
    np.testing.assert_allclose(np.asarray(y1), np.asarray(y0),
                               rtol=1e-5, atol=1e-5)


def test_padded_init_zero_rows():
    cfg = replace(get_reduced("qwen3-14b"), pad_q_heads=8, pad_kv_heads=4)
    p = attn_mod.init_attn(cfg, jax.random.PRNGKey(0))
    H, Hkv = 4, 2                                  # reduced real counts
    g, gp = H // Hkv, 8 // 4
    wq = p["wq"].reshape(cfg.d_model, 4, 2, cfg.head_dim)
    assert float(jnp.abs(wq[:, Hkv:]).max()) == 0.0
    assert float(jnp.abs(wq[:, :Hkv, g:]).max()) == 0.0 if gp > g else True
    assert float(jnp.abs(wq[:, :Hkv, :g]).max()) > 0.0


def test_padded_model_forward_finite():
    cfg = replace(get_reduced("qwen3-14b"), pad_q_heads=8, pad_kv_heads=4)
    params = init_params(cfg, jax.random.PRNGKey(0))
    batch = {"tokens": jnp.zeros((2, 16), jnp.int32),
             "labels": jnp.zeros((2, 16), jnp.int32)}
    logits, _ = forward(cfg, params, batch, impl=Impl(remat=False, q_chunk=8,
                                                      kv_chunk=8),
                        dtype=jnp.float32)
    assert np.isfinite(np.asarray(logits[..., :cfg.vocab_size])).all()
