"""MPKLinkFabric guarded collectives on an 8-device mesh (subprocess —
jax locks the device count per process)."""
import os
import subprocess
import sys

import pytest

_ROOT = os.path.join(os.path.dirname(__file__), "..")

FABRIC_CODE = """
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import jax, jax.numpy as jnp, numpy as np
from jax.sharding import PartitionSpec as P
from repro.utils import shard_map
from repro.core.fabric import (MPKLinkFabric, neighbor_exchange, ring_all_gather,
                               reduce_scatter_ring, all_to_all)
from repro.core.domains import AccessViolation

mesh = jax.make_mesh((8,), ("x",))
fab = MPKLinkFabric(mesh, guard=True)
chan, key = fab.establish("tp", "x")
x = jnp.arange(8 * 4, dtype=jnp.float32).reshape(8, 4)

def allok(ok):
    return (jax.lax.psum(1 - ok, "x") == 0).astype(jnp.int32)

def ne(xl):
    y, ok = neighbor_exchange(fab, chan, key, xl, shift=1)
    return y, allok(ok)
y, ok = jax.jit(shard_map(ne, mesh=mesh, in_specs=P("x"), out_specs=(P("x"), P())))(x)
np.testing.assert_allclose(y, jnp.roll(x, 1, axis=0))
assert int(ok) == 1

def ag(xl):
    g, ok = ring_all_gather(fab, chan, key, xl)
    return g, allok(ok)
g, ok = jax.jit(shard_map(ag, mesh=mesh, in_specs=P("x"), out_specs=(P("x"), P())))(x)
g = np.asarray(g).reshape(8, 8, 4)
for d in range(8):
    np.testing.assert_allclose(g[d], x)
assert int(ok) == 1

xs = jnp.arange(8 * 8 * 4, dtype=jnp.float32).reshape(8, 8, 4)
def rs(xl):
    s, ok = reduce_scatter_ring(fab, chan, key, xl[0])
    return s, allok(ok)
s, ok = jax.jit(shard_map(rs, mesh=mesh, in_specs=P("x"), out_specs=(P("x"), P())))(xs)
np.testing.assert_allclose(np.asarray(s), np.asarray(xs).sum(0))
assert int(ok) == 1

# all_to_all (EP dispatch channel): local (1, 8) split on dim 1, concat on
# dim 0 → device d collects element d of every source row == transpose
def a2a(xl):
    return all_to_all(fab, chan, key, xl, split_axis=1, concat_axis=0)
t = jnp.arange(8 * 8, dtype=jnp.float32).reshape(8, 8)
out = jax.jit(shard_map(a2a, mesh=mesh, in_specs=P("x"), out_specs=P("x")))(t)
np.testing.assert_allclose(np.asarray(out).reshape(8, 8), np.asarray(t).T)

# trace-time violations
chan2, key2 = fab.establish("other", "x")
try:
    jax.jit(shard_map(lambda xl: neighbor_exchange(fab, chan, key2, xl)[0],
                      mesh=mesh, in_specs=P("x"), out_specs=P("x")))(x)
    raise SystemExit("FAIL: foreign key accepted")
except AccessViolation:
    pass
fab.revoke(chan2)
try:
    jax.jit(shard_map(lambda xl: neighbor_exchange(fab, chan2, key2, xl)[0],
                      mesh=mesh, in_specs=P("x"), out_specs=P("x")))(x)
    raise SystemExit("FAIL: revoked key accepted")
except AccessViolation:
    pass
print("OK")
"""


def test_fabric_collectives_and_capabilities():
    env = dict(os.environ, PYTHONPATH="src")
    r = subprocess.run([sys.executable, "-c", FABRIC_CODE], capture_output=True,
                       text=True, cwd=_ROOT, env=env, timeout=480)
    assert "OK" in r.stdout, r.stdout + r.stderr
