"""Multi-tenant QoS (docs/protocol.md §10): the lane-12 priority word,
per-identity token-bucket rate limits with typed ``RateLimited`` sheds,
deficit-round-robin fair queuing (shard executor + fleet slot gate), and
the cross-feature invariants — rate-limit sheds never charge brownout
(no double penalty) and a dry retry budget refills from later primaries.
"""
import random
import threading
import time

import numpy as np
import pytest

from repro.core import ServiceGateway, framing
from repro.core.gateway import (RetryBudget, TokenBucket, WeightedFairQueue,
                                WFQ_QUANTUM, _FairGate, _Shard,
                                current_priority, priority_rank)
from repro.core.transports import (DeadlineExpired, Overloaded, RateLimited,
                                   ServiceUnavailable)
from repro.core.wordcount import make_text, parse_count, wordcount_handler


def _echo(req):
    return np.ascontiguousarray(np.asarray(req))


def _payload(i=0):
    return np.arange(i, i + 4, dtype=np.int32)


# ---------------------------------------------------------------------------
# token bucket + RateLimited over the wire
# ---------------------------------------------------------------------------

def test_token_bucket_unit():
    b = TokenBucket(rate=10.0, burst=2.0)
    assert b.try_take() == 0.0
    assert b.try_take() == 0.0
    wait = b.try_take()
    assert wait > 0.0
    # retry_after is the exact deficit: < 1 token missing at 10/s
    assert wait <= 0.1 + 1e-6
    assert b.admitted == 2 and b.shed == 1
    time.sleep(wait + 0.02)
    assert b.try_take() == 0.0          # refilled at the promised time
    with pytest.raises(ValueError):
        TokenBucket(rate=0.0, burst=1.0)
    with pytest.raises(ValueError):
        TokenBucket(rate=1.0, burst=0.5)


def test_rate_limit_sheds_typed_and_isolates_tenants():
    """The abuser's bucket sheds typed RateLimited (retry_after crosses
    the wire, isinstance-compatible with Overloaded backoff handlers);
    the victim identity is untouched."""
    gw = ServiceGateway("mpklink_opt")
    gw.register_service("echo", _echo)
    gw.start()
    try:
        gw.set_rate_limit("abuser", rate=5.0, burst=2)
        abuser = gw.connect("abuser")
        victim = gw.connect("victim")
        abuser.call("echo", _payload())
        abuser.call("echo", _payload())
        with pytest.raises(RateLimited) as ei:
            abuser.call("echo", _payload())
        assert ei.value.retry_after > 0.0
        assert isinstance(ei.value, Overloaded)          # §7 taxonomy
        assert isinstance(ei.value, ServiceUnavailable)
        # the victim never competes with the abuser's bucket
        for i in range(8):
            np.testing.assert_array_equal(
                np.asarray(victim.call("echo", _payload(i))), _payload(i))
        assert gw.stats["rate_limited"] >= 1
        qs = gw.qos_stats()["abuser"]
        assert qs["rate"] == 5.0 and qs["shed"] >= 1 and qs["admitted"] == 2
        # a cooperative client that waits retry_after is admitted again
        time.sleep(ei.value.retry_after + 0.05)
        abuser.call("echo", _payload())
        abuser.close()
        victim.close()
    finally:
        gw.close()


def test_rate_limit_batch_envelope_is_atomic():
    """A batch envelope is admitted or shed whole (n tokens) — a shed
    executes zero items and is fully replayable after refill."""
    gw = ServiceGateway("mpklink_opt")
    gw.register_service("wordcount", wordcount_handler)
    gw.start()
    try:
        gw.set_rate_limit("bulk", rate=50.0, burst=4)
        c = gw.connect("bulk")
        before = gw.stats["responses"]
        with pytest.raises(RateLimited) as ei:
            c.call_batch("wordcount", [make_text(10, seed=j)
                                       for j in range(6)])
        assert gw.stats["responses"] == before      # nothing executed
        time.sleep(ei.value.retry_after + 0.05)
        outs = c.call_batch("wordcount", [make_text(10, seed=j)
                                          for j in range(4)])
        assert [parse_count(o) for o in outs] == [10] * 4
        c.close()
    finally:
        gw.close()


def test_rate_limit_charges_caller_not_coalescer_carrier():
    """Coalesced calls are charged against the CALLER identity before
    folding into the carrier mux — multiplexing is not a laundering
    path (§10.2)."""
    gw = ServiceGateway("mpklink_opt", max_keys=128)
    gw.register_service("wordcount", wordcount_handler)
    gw.start()
    gw.enable_coalescing(max_batch=8, max_wait_us=200.0)
    try:
        gw.set_rate_limit("greedy", rate=2.0, burst=1)
        c = gw.connect("greedy")
        assert parse_count(c.call("wordcount", make_text(7))) == 7
        with pytest.raises(RateLimited):
            c.call("wordcount", make_text(7))
        assert gw.qos_stats()["greedy"]["shed"] >= 1
        c.close()
    finally:
        gw.close()


# ---------------------------------------------------------------------------
# lane-12 priority word
# ---------------------------------------------------------------------------

def test_priority_lane_roundtrip_and_mac_covered():
    arr = np.arange(16, dtype=np.int32)
    for prio in (framing.PRIO_NORMAL, framing.PRIO_HIGH, framing.PRIO_BULK):
        f = framing.build_frame(arr, seed=7, seq=0, priority=prio)
        assert framing.frame_priority(f) == prio
        out = framing.parse_frame(f, seed=7, expect_seq=0)
        np.testing.assert_array_equal(np.asarray(out), arr)
    # flipping the priority word breaks the MAC like any header bit
    f = framing.build_frame(arr, seed=7, seq=1, priority=framing.PRIO_HIGH)
    bad = f.copy()
    bad[0, framing.PRIORITY_LANE] = framing.PRIO_BULK
    with pytest.raises(framing.FrameError):
        framing.parse_frame(bad, seed=7, expect_seq=1)
    # out-of-range class is rejected even with a recomputed-looking word
    with pytest.raises(framing.FrameError):
        framing.parse_frame(
            _with_lane(f, framing.PRIORITY_LANE, 3), seed=7, expect_seq=1)


def _with_lane(frame, lane, value):
    out = frame.copy()
    out[0, lane] = value
    return out


def test_priority_rank_total_order():
    order = sorted([framing.PRIO_BULK, framing.PRIO_HIGH,
                    framing.PRIO_NORMAL], key=priority_rank)
    assert order == [framing.PRIO_HIGH, framing.PRIO_NORMAL,
                     framing.PRIO_BULK]
    assert priority_rank(99) == priority_rank(framing.PRIO_NORMAL)


def test_priority_reaches_handler_thread_local():
    """The lane-12 word is decoded at dispatch and published to the
    handler via current_priority() — per call, reverting after."""
    seen = []

    def handler(req):
        seen.append(current_priority())
        return _echo(req)

    gw = ServiceGateway("mpklink_opt")
    gw.register_service("echo", handler)
    gw.start()
    try:
        c = gw.connect("cli")
        c.call("echo", _payload())
        c.call("echo", _payload(), priority=framing.PRIO_HIGH)
        c.call("echo", _payload(), priority=framing.PRIO_BULK)
        outs = c.call_many(
            [("echo", _payload(i)) for i in range(2)],
            priorities=[framing.PRIO_HIGH, framing.PRIO_HIGH])
        assert len(outs) == 2
        assert seen[:3] == [framing.PRIO_NORMAL, framing.PRIO_HIGH,
                            framing.PRIO_BULK]
        assert all(p == framing.PRIO_HIGH for p in seen[3:])
        assert current_priority() == framing.PRIO_NORMAL    # reverted
        c.close()
    finally:
        gw.close()


def test_coalescer_high_priority_skips_wait_window():
    """A HIGH entry collapses the coalescer window to zero: with a large
    max_wait_us and no other traffic the call must return far sooner
    than the bulk window would allow (§10.1)."""
    gw = ServiceGateway("mpklink_opt", max_keys=128)
    gw.register_service("wordcount", wordcount_handler)
    gw.start()
    mux = gw.enable_coalescing(max_batch=32, max_wait_us=300_000.0)
    try:
        c = gw.connect("cli")
        c.call("wordcount", make_text(5))       # warm the channel + mux
        t0 = time.monotonic()
        n = parse_count(c.call("wordcount", make_text(9),
                               priority=framing.PRIO_HIGH))
        elapsed = time.monotonic() - t0
        assert n == 9
        assert elapsed < 0.15, f"HIGH call waited {elapsed:.3f}s"
        assert mux.stats["cohorts"] >= 1
        c.close()
    finally:
        gw.close()


# ---------------------------------------------------------------------------
# weighted fair queuing: DRR queue, shard executor, fleet slot gate
# ---------------------------------------------------------------------------

def test_wfq_interleaves_by_quantum():
    q = WeightedFairQueue(weight_of=lambda k: 1.0)
    for i in range(8):
        q.push(("a", i), key="a", cost=1)
    for i in range(8):
        q.push(("b", i), key="b", cost=1)
    order = []
    while True:
        got = q.pop(timeout=0.0)
        if got is None:
            break
        order.append(got[0][0])
    # quantum=4 → four units per flow per round, FIFO within a flow
    assert "".join(order) == "aaaabbbbaaaabbbb"


def test_wfq_share_tracks_weight():
    q = WeightedFairQueue(weight_of=lambda k: 2.0 if k == "heavy" else 1.0)
    for i in range(24):
        q.push(("heavy", i), key="heavy", cost=1)
        q.push(("light", i), key="light", cost=1)
    first = [q.pop(timeout=0.0)[0][0] for _ in range(12)]
    share = first.count("heavy") / 12
    assert share >= 7 / 12, first       # 2:1 weights → ~2/3 of early service


def test_wfq_single_flow_is_fifo():
    q = WeightedFairQueue(weight_of=lambda k: 1.0)
    for i in range(10):
        q.push(i, key="only", cost=3)   # cost > quantum still drains FIFO
    out = []
    while True:
        got = q.pop(timeout=0.0)
        if got is None:
            break
        out.append(got[0])
    assert out == list(range(10))


def test_wfq_close_drains_then_signals():
    q = WeightedFairQueue(weight_of=lambda k: 1.0)
    q.push("x", key="a", cost=1)
    q.close()
    assert q.pop(timeout=1.0)[0] == "x"     # close drains queued work
    assert q.pop(timeout=0.05) is None      # then reports closed


def test_shard_executor_interleaves_tenants():
    """The sharded executor serves backlogged tenants round-robin: a
    flood queued first no longer runs ahead of the victim's entire
    backlog (§10.3)."""
    gate = threading.Event()
    order = []

    def work(tag):
        def fn():
            gate.wait(5.0)
            order.append(tag)
        return fn

    sh = _Shard(0, weight_of=lambda k: 1.0)
    try:
        boxes = []
        # the flood lands first...
        for i in range(2 * WFQ_QUANTUM):
            boxes.append(sh.submit(work("flood"), key="flood", cost=1))
        # ...then the victim queues behind it
        for i in range(WFQ_QUANTUM):
            boxes.append(sh.submit(work("victim"), key="victim", cost=1))
        gate.set()
        for box, done in boxes:
            assert done.wait(10.0)
        # the victim's first item ran within the first flood quantum + 1
        first_victim = order.index("victim")
        assert first_victim <= WFQ_QUANTUM, order
    finally:
        sh.close()


def test_fair_gate_blocks_at_capacity_and_shares():
    g = _FairGate(2, weight_of=lambda k: 1.0)
    assert g.acquire("a", 1, None)
    assert g.acquire("a", 1, None)
    assert g.inflight() == 2
    t0 = time.monotonic()
    assert not g.acquire("b", 1, time.monotonic() + 0.05)
    assert time.monotonic() - t0 >= 0.04    # parked until the deadline
    assert g.inflight() == 2                 # expired waiter charged nothing
    g.release(1)
    assert g.acquire("b", 1, time.monotonic() + 1.0)
    g.release(1)
    g.release(1)
    assert g.inflight() == 0


def test_fair_gate_oversized_cohort_admits_alone():
    g = _FairGate(4, weight_of=lambda k: 1.0)
    assert g.acquire("big", 32, None)        # clamped to capacity
    assert not g.acquire("small", 1, time.monotonic() + 0.05)
    g.release(32)                            # symmetric clamp — drains fully
    assert g.inflight() == 0
    assert g.acquire("small", 1, None)
    g.release(1)


def test_fleet_fair_queue_end_to_end():
    """Fair queuing over fleet slots: both tenants complete under a
    capacity-1 gate, double-enable is an error, and a waiter whose
    deadline expires at the gate sheds typed DeadlineExpired."""
    def slow(req):
        time.sleep(0.02)
        return _echo(req)

    gw = ServiceGateway("mpklink_opt")
    for _ in range(2):
        gw.register_replica("echo", slow, transport="mpklink_opt")
    gw.start()
    fleet = gw.fleet("echo")
    fleet.enable_fair_queue(1)
    with pytest.raises(RuntimeError):
        fleet.enable_fair_queue(1)
    try:
        errs = []

        def run(name, reps):
            try:
                c = gw.connect(name)
                for i in range(reps):
                    out = c.call("echo", _payload(i))
                    assert np.asarray(out).tobytes() == _payload(i).tobytes()
                c.close()
            except Exception as e:      # pragma: no cover - surfaced below
                errs.append((name, repr(e)))

        ts = [threading.Thread(target=run, args=(f"tenant-{i}", 6))
              for i in range(2)]
        for t in ts:
            t.start()
        for t in ts:
            t.join(60)
        assert not errs, errs
        assert fleet.stats["fair_queued"] >= 12
        # a queued waiter with a spent budget sheds typed at the gate
        blocker = gw.connect("blocker")
        hurried = gw.connect("hurried")
        # occupy the only slot with a slow call, then race a tiny budget
        hold = threading.Thread(
            target=lambda: blocker.call("echo", _payload()))
        hold.start()
        time.sleep(0.005)
        with pytest.raises(DeadlineExpired):
            hurried.call("echo", _payload(), timeout=0.01)
        hold.join(30)
        blocker.close()
        hurried.close()
    finally:
        gw.close()


# ---------------------------------------------------------------------------
# cross-feature invariants (ISSUE satellites 2 + 3)
# ---------------------------------------------------------------------------

def test_retry_budget_refills_after_running_dry():
    """Regression (§9.3): primaries completing AFTER the bucket ran dry
    still earn ratio tokens — a dry budget must not disable retries
    forever."""
    b = RetryBudget(ratio=0.5, burst=1, initial=0.0)
    assert not b.take()                 # dry: extra attempt refused
    b.note_primary()
    b.note_primary()
    assert b.tokens() == pytest.approx(1.0)
    assert b.take()                     # refilled by later primaries
    assert b.spent == 1 and b.denied == 1


def test_fleet_primaries_earn_budget_when_dry():
    """The fleet dispatch path calls note_primary() on completion even
    when the budget started empty — hedging recovers."""
    gw = ServiceGateway("mpklink_opt")
    for _ in range(2):
        gw.register_replica("echo", _echo, transport="mpklink_opt")
    gw.start()
    try:
        budget = RetryBudget(ratio=0.25, burst=2, initial=0.0)
        gw.fleet("echo").enable_hedging(delay=30.0, budget=budget)
        c = gw.connect("cli")
        for i in range(4):
            c.call("echo", _payload(i))
        assert budget.tokens() == pytest.approx(1.0)
        c.close()
    finally:
        gw.close()


@pytest.mark.parametrize("seed", [0, 1, 2, 3])
def test_rate_limited_sheds_never_charge_brownout(seed):
    """Property (§10.2, no double penalty): across randomized admit/shed
    interleavings, a RateLimited shed must not move the brownout gauge —
    brownout admissions equal successful responses, the gauge drains to
    zero, and brownout itself never engages from rate-limit pressure."""
    rng = random.Random(seed)
    gw = ServiceGateway("mpklink_opt")
    gw.register_service("echo", _echo)
    gw.start()
    bo = gw.enable_brownout("echo", high_water=64)
    try:
        gw.set_rate_limit("noisy", rate=20.0, burst=2)
        noisy = gw.connect("noisy")
        quiet = gw.connect("quiet")
        ok = limited = 0
        for i in range(40):
            c, tag = (noisy, "noisy") if rng.random() < 0.6 \
                else (quiet, "quiet")
            try:
                if rng.random() < 0.25:
                    c.call_batch("echo", [_payload(i), _payload(i + 1)])
                    ok += 2
                else:
                    c.call("echo", _payload(i))
                    ok += 1
            except RateLimited:
                assert tag == "noisy"   # only the bucketed tenant sheds
                limited += 1
        assert limited > 0              # the interleaving exercised sheds
        snap = bo.snapshot()
        assert snap["inflight"] == 0    # gauge fully drained
        assert snap["sheds"] == 0       # rate-limit never became brownout
        assert snap["engagements"] == 0
        assert gw.stats["responses"] == ok
        noisy.close()
        quiet.close()
    finally:
        gw.close()


def test_serving_engine_admits_by_priority():
    """ServingEngine._admit boards the most urgent class first, FIFO
    within a class (pure FIFO when everything is PRIO_NORMAL)."""
    jax = pytest.importorskip("jax")
    from repro.configs import get_reduced
    from repro.models import init_params
    from repro.models.transformer import Impl
    from repro.runtime import Request, ServingEngine

    cfg = get_reduced("llama3.2-1b")
    params = init_params(cfg, jax.random.PRNGKey(0))
    eng = ServingEngine(cfg, params, max_batch=1, max_seq=32,
                        impl=Impl(attention="naive", remat=False))
    eng.submit(Request(rid=0, prompt=[1, 2], max_new=2,
                       priority=framing.PRIO_NORMAL))
    eng.submit(Request(rid=1, prompt=[3, 4], max_new=2,
                       priority=framing.PRIO_BULK))
    eng.submit(Request(rid=2, prompt=[5, 6], max_new=2,
                       priority=framing.PRIO_HIGH))
    done = eng.run_until_drained()
    assert [r.rid for r in done] == [2, 0, 1]
