"""Roofline HLO cost model: trip counts, flops, collective accounting."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.roofline import Roofline, analyze, model_flops
from repro.roofline.hlo_parse import ModuleCost


def _flops_of(fn, *args):
    c = jax.jit(fn).lower(*args).compile()
    return ModuleCost(c.as_text(), 1).total()


def test_scan_trip_count_exact():
    x = jax.ShapeDtypeStruct((128, 128), jnp.float32)
    for k in (2, 4, 8):
        def f(x, k=k):
            return jax.lax.scan(lambda c, _: (c @ c, None), x, None, length=k)[0]
        got = _flops_of(f, x).flops
        assert abs(got - 2 * k * 128 ** 3) / (2 * k * 128 ** 3) < 0.01, (k, got)


def test_nested_scan_multiplies():
    x = jax.ShapeDtypeStruct((64, 64), jnp.float32)

    def f(x):
        def outer(c, _):
            def inner(c2, _):
                return c2 @ c2, None
            c, _ = jax.lax.scan(inner, c, None, length=3)
            return c, None
        return jax.lax.scan(outer, x, None, length=5)[0]

    got = _flops_of(f, x).flops
    want = 2 * 15 * 64 ** 3
    assert abs(got - want) / want < 0.02, got


def test_dot_general_contracted_dims():
    a = jax.ShapeDtypeStruct((8, 32, 16), jnp.float32)
    b = jax.ShapeDtypeStruct((8, 16, 24), jnp.float32)

    def f(a, b):
        return jnp.einsum("bik,bkj->bij", a, b)

    got = _flops_of(f, a, b).flops
    want = 2 * 8 * 32 * 24 * 16
    assert abs(got - want) / want < 0.05, got


def test_collective_accounting_multidevice():
    import subprocess, sys, os
    code = """
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import jax, jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P
from repro.roofline.hlo_parse import ModuleCost

mesh = jax.make_mesh((8,), ("x",))
sh = NamedSharding(mesh, P("x"))
rep = NamedSharding(mesh, P())
x = jax.ShapeDtypeStruct((64, 32), jnp.float32)

# all-gather: sharded → replicated
c = jax.jit(lambda v: v * 1.0, in_shardings=sh, out_shardings=rep).lower(x).compile()
mc = ModuleCost(c.as_text(), 8).total()
assert mc.coll_by_kind.get("all-gather", 0) > 0, mc.coll_by_kind
# (g-1)/g × full result bytes = 7/8 × 8192
assert abs(mc.coll_by_kind["all-gather"] - 7/8 * 64*32*4) < 1024, mc.coll_by_kind

# psum: all-reduce
def f(v):
    return jax.lax.with_sharding_constraint(
        jnp.broadcast_to(v.sum(axis=0, keepdims=True), v.shape), P())
c2 = jax.jit(lambda v: v.sum(), in_shardings=sh).lower(x).compile()
mc2 = ModuleCost(c2.as_text(), 8).total()
assert mc2.coll_by_kind.get("all-reduce", 0) > 0, mc2.coll_by_kind
print("OK")
"""
    env = dict(os.environ, PYTHONPATH="src")
    r = subprocess.run([sys.executable, "-c", code], capture_output=True,
                       text=True, cwd=os.path.join(os.path.dirname(__file__), ".."),
                       env=env, timeout=300)
    assert "OK" in r.stdout, r.stdout + r.stderr


def test_roofline_terms_and_bottleneck():
    r = Roofline(flops=197e12, hbm_bytes=819e9 / 2, collective_bytes=0,
                 n_collectives=0, by_kind={})
    assert abs(r.t_compute - 1.0) < 1e-9
    assert abs(r.t_memory - 0.5) < 1e-9
    assert r.bottleneck == "compute"
    assert r.t_bound == r.t_compute


def test_model_flops():
    assert model_flops(1_000_000, 100, "train") == 6e8
    assert model_flops(1_000_000, 100, "prefill") == 2e8


def test_full_model_flops_sane():
    """Parsed HLO flops for a reduced dense model ≈ analytic 6·N·D within
    the expected overhead envelope (remat off, naive attention)."""
    from repro.configs import get_reduced
    from repro.models import init_params, loss_fn
    from repro.models.transformer import Impl

    cfg = get_reduced("llama3.2-1b")
    params = init_params(cfg, jax.random.PRNGKey(0))
    B, S = 2, 32
    batch = {"tokens": jnp.zeros((B, S), jnp.int32),
             "labels": jnp.zeros((B, S), jnp.int32)}
    impl = Impl(attention="naive", remat=False)

    def train(p, b):
        return jax.grad(lambda p: loss_fn(cfg, p, b, impl=impl,
                                          dtype=jnp.float32)[0])(p)

    c = jax.jit(train).lower(params, batch).compile()
    got = ModuleCost(c.as_text(), 1).total().flops
    want = 6 * cfg.param_count() * B * S
    # naive attention adds O(S²) terms; tiny model → generous envelope
    assert want * 0.5 < got < want * 6, (got, want)
