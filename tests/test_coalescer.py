"""Auto-batching gateway mux (CallCoalescer): transparent cohort formation
with inline-call semantics preserved bit-for-bit.

Contract under test (normative in docs/protocol.md §5.4):

* concurrent inline ``GatewayClient.call()``s fold into scatter envelopes —
  fewer wire round trips than requests, answers unchanged, every frame
  still MAC-verified on both sides;
* per-item isolation: a poisoned cohort item fails typed while its
  cohort-mates complete;
* idempotency: a cohort envelope whose response is lost is replayed with
  the SAME tokens — items the envelope executed are answered from the
  gateway dedup window, never re-executed;
* authorization is the CALLER's: allow-lists are enforced per client
  before folding, and a service that refuses the carrier identity keeps
  the direct path;
* a service with a native ``batch_handler`` admits a coalesced cohort as
  ONE unit (EngineService: one continuous-batching submission);
* all 8 FaultPlan kinds against auto-coalesced traffic stay typed and
  bounded.
"""
import threading
import time

import numpy as np
import pytest

from repro.core import ServiceGateway, framing
from repro.core.domains import AccessViolation
from repro.core.faultwire import (CLIENT_KINDS, EXPECTED, FaultFabric,
                                  FaultPlan, FaultyClient)
from repro.core.transports import TransportError
from repro.core.wordcount import make_text, parse_count, wordcount_handler

WALL_BUDGET = 90.0


def _mux_gateway(transport="mpklink_opt", *, timeout=30.0, factory=True,
                 max_batch=32, max_wait_us=400.0, **svc_kw):
    gw = ServiceGateway(transport, max_keys=512,
                        transport_kwargs={"timeout": timeout})
    gw.register_service(
        "wordcount", wordcount_handler,
        factory=(lambda: wordcount_handler) if factory else None, **svc_kw)
    gw.start()
    mux = gw.enable_coalescing(max_batch=max_batch, max_wait_us=max_wait_us)
    return gw, mux


def _hammer(gw, n_clients, reps, payload_fn=None, service="wordcount"):
    """n_clients threads, each its own GatewayClient, all calling inline
    through the mux. Returns (results per (i, j), error list)."""
    clients = [gw.connect(f"co-{i}") for i in range(n_clients)]
    for c in clients:
        c.open(service)
    results: dict = {}
    errors: list = []
    barrier = threading.Barrier(n_clients)

    def worker(i):
        try:
            barrier.wait()
            for j in range(reps):
                p = payload_fn(i, j) if payload_fn \
                    else make_text(3 + (i + j) % 7, seed=i * 131 + j)
                results[(i, j)] = clients[i].call(service, p)
        except Exception as e:
            errors.append((i, e))

    ts = [threading.Thread(target=worker, args=(i,)) for i in range(n_clients)]
    for t in ts:
        t.start()
    for t in ts:
        t.join(WALL_BUDGET)
    return clients, results, errors


def test_cohorts_form_and_answers_stay_correct():
    gw, mux = _mux_gateway()
    try:
        n_clients, reps = 12, 6
        clients, results, errors = _hammer(gw, n_clients, reps)
        total = n_clients * reps
        assert not errors, errors[:3]
        for (i, j), out in results.items():
            assert parse_count(out) == 3 + (i + j) % 7
        assert mux.stats["coalesced_calls"] == total
        assert mux.stats["cohorts"] < total, "nothing coalesced"
        assert mux.stats["max_cohort"] > 1
        # every request MAC-verified on both sides despite the folding
        assert gw.stats["macs_verified"] >= total
        assert mux._carrier.macs_verified == total
        assert gw.stats["rejected"] == 0
    finally:
        gw.close()


def test_single_caller_stays_ordered_and_correct():
    gw, mux = _mux_gateway()
    try:
        c = gw.connect("solo")
        c.open("wordcount")
        for j in range(10):
            assert parse_count(c.call("wordcount",
                                      make_text(j + 1, seed=j))) == j + 1
        assert mux.stats["coalesced_calls"] == 10
    finally:
        gw.close()


def test_poisoned_item_does_not_fail_cohort_mates():
    """One caller sends payloads its handler rejects; cohort-mates in the
    same envelope must complete normally — per-item typed errors."""
    def picky(req):
        raw = np.asarray(req).reshape(-1).view(np.uint8)
        if raw[:6].tobytes() == b"poison":
            raise ValueError("poisoned payload refused")
        return wordcount_handler(req)

    gw = ServiceGateway("mpklink_opt", max_keys=512,
                        transport_kwargs={"timeout": 30.0})
    gw.register_service("picky", picky)
    gw.start()
    mux = gw.enable_coalescing(max_batch=32, max_wait_us=2000.0)
    try:
        def payload(i, j):
            if i == 0:
                return np.frombuffer(b"poison", np.uint8)
            return make_text(3 + (i + j) % 5, seed=i * 7 + j)

        clients, results, errors = _hammer(gw, 8, 4, payload, service="picky")
        # caller 0's calls failed typed; everyone else's succeeded
        poisoned = [e for (i, e) in errors if i == 0]
        assert poisoned and all(isinstance(e, TransportError)
                                for e in poisoned), errors
        assert all(i == 0 for i, _ in errors), errors
        for (i, j), out in results.items():
            assert i != 0
            assert parse_count(out) == 3 + (i + j) % 5
        assert mux.stats["max_cohort"] > 1
    finally:
        gw.close()


def test_dropped_cohort_response_never_double_executes():
    """drop_response on a cohort envelope: every item already executed, so
    the mux's same-token inline replay is answered from the dedup window —
    the handler runs each request exactly once."""
    calls = []

    def counting(req):
        calls.append(1)
        return wordcount_handler(req)

    gw = ServiceGateway("mpklink_opt", max_keys=512,
                        transport_kwargs={"timeout": 0.4})
    gw.register_service("wordcount", counting,
                        factory=lambda: counting)
    gw.start()
    mux = gw.enable_coalescing(max_batch=16, max_wait_us=300.0)
    plan = FaultPlan(seed=11, n_requests=24, rate=0.2,
                     kinds=("drop_response",))
    fab = FaultFabric(plan).attach(gw)
    try:
        c = gw.connect("dropper")
        c.open("wordcount")
        t0 = time.perf_counter()
        for j in range(plan.n_requests):
            n = 4 + j % 5
            assert parse_count(c.call("wordcount",
                                      make_text(n, seed=j))) == n
        wall = time.perf_counter() - t0
        assert wall < WALL_BUDGET
        n_drops = len([e for e in fab.fired if e.kind == "drop_response"])
        assert n_drops >= 1, "plan fired no drops — test is vacuous"
        assert len(calls) == plan.n_requests, \
            f"{len(calls)} executions for {plan.n_requests} requests"
        # every drop (cohort envelope OR replay) is answered from the dedup
        # window exactly once downstream; replays that were themselves
        # dropped ride the carrier's bounded retry within one fallback item
        assert gw.stats["deduped"] == n_drops
        assert mux.stats["fallback_items"] >= 1
    finally:
        fab.detach()
        gw.close()


def test_crashed_cohort_recovers_per_item():
    """crash_handler kills the carrier's session mid-envelope (before any
    handler ran): the mux heals and replays inline — every caller still
    gets its correct answer, typed and bounded."""
    gw, mux = _mux_gateway(timeout=0.4)
    plan = FaultPlan(seed=7, n_requests=20, rate=0.2,
                     kinds=("crash_handler",))
    fab = FaultFabric(plan).attach(gw)
    try:
        clients, results, errors = _hammer(gw, 6, 4)
        assert not errors, errors[:3]
        for (i, j), out in results.items():
            assert parse_count(out) == 3 + (i + j) % 7
        assert len(fab.fired) >= 1
        assert mux.stats["fallback_items"] >= 1
    finally:
        fab.detach()
        gw.close()


def test_stale_epoch_rekeys_transparently_under_coalescing():
    """A revocation bumps the service-domain epoch mid-run; the mux re-keys
    through the CA and the coalesced calls keep succeeding — same
    transparent recovery as the direct path."""
    gw, mux = _mux_gateway()
    try:
        c = gw.connect("rekey")
        c.open("wordcount")
        assert parse_count(c.call("wordcount", make_text(4, seed=0))) == 4
        victim = gw.connect("victim")
        victim.open("wordcount")
        gw.revoke(victim, "wordcount")          # epoch bump: carrier stale
        assert parse_count(c.call("wordcount", make_text(6, seed=1))) == 6
        assert mux.stats["rekeys"] >= 1
    finally:
        gw.close()


def test_caller_acl_enforced_before_folding():
    """A client outside the allow-list must be rejected even though the
    (allowed) carrier would have accepted the envelope — folding cannot
    launder authorization."""
    gw = ServiceGateway("mpklink_opt", max_keys=512)
    gw.register_service("vip", wordcount_handler,
                        allow={"alice", "gw:coalescer"})
    gw.start()
    gw.enable_coalescing()
    try:
        alice = gw.connect("alice")
        assert parse_count(alice.call("vip", make_text(5, seed=0))) == 5
        mallory = gw.connect("mallory")
        with pytest.raises(AccessViolation):
            mallory.call("vip", make_text(5, seed=0))
    finally:
        gw.close()


def test_service_refusing_carrier_keeps_direct_path():
    """An allow-list that excludes the carrier identity silently disables
    coalescing for that service — calls still work, directly."""
    gw = ServiceGateway("mpklink_opt", max_keys=512)
    gw.register_service("private", wordcount_handler, allow={"bob"})
    gw.start()
    mux = gw.enable_coalescing()
    try:
        bob = gw.connect("bob")
        assert parse_count(bob.call("private", make_text(4, seed=0))) == 4
        assert not mux.accepts("private")
        assert mux.stats["coalesced_calls"] == 0
    finally:
        gw.close()


def test_closed_mux_falls_back_to_direct_calls():
    gw, mux = _mux_gateway()
    try:
        c = gw.connect("after-close")
        c.open("wordcount")
        assert parse_count(c.call("wordcount", make_text(3, seed=0))) == 3
        mux.close()
        assert parse_count(c.call("wordcount", make_text(5, seed=1))) == 5
    finally:
        gw.close()


def test_adaptive_window_tracks_arrival_rate():
    gw, mux = _mux_gateway(max_batch=64, max_wait_us=300.0)
    try:
        cap = 300.0 / 1e6
        mux._ewma_gap = None                    # no history: full window
        assert mux._window_s() == cap
        mux._ewma_gap = 1e-6                    # dense burst: scale to fill
        assert mux._window_s() == pytest.approx(63e-6)
        mux._ewma_gap = 1.0                     # sparse: don't wait at all
        assert mux._window_s() == 0.0
    finally:
        gw.close()


def test_batch_handler_admits_cohort_as_one_unit():
    """A coalesced cohort for a batch_handler service executes as ONE
    native batch call (the scatter channel-group cohort path)."""
    sizes = []

    def batch_wc(payloads):
        sizes.append(len(payloads))
        return [wordcount_handler(p) for p in payloads]

    gw = ServiceGateway("mpklink_opt", max_keys=512)
    gw.register_service("wc", wordcount_handler, batch_handler=batch_wc)
    gw.start()
    gw.enable_coalescing(max_batch=32, max_wait_us=3000.0)
    try:
        clients, results, errors = _hammer(gw, 8, 3, service="wc")
        assert not errors, errors[:3]
        for (i, j), out in results.items():
            assert parse_count(out) == 3 + (i + j) % 7
        assert sum(sizes) == 24, "some items bypassed the batch handler"
        assert max(sizes) > 1, "no cohort reached the batch handler whole"
    finally:
        gw.close()


def test_engine_service_cohort_joins_decode_grid_as_one_unit():
    """The real serving path: auto-coalesced inline inference calls reach
    EngineService.handler_batch as one cohort submission."""
    import jax
    from repro.configs import get_reduced
    from repro.models import init_params
    from repro.models.transformer import Impl
    from repro.runtime import EngineService, ServingEngine, encode_prompt

    cfg = get_reduced("llama3.2-1b")
    params = init_params(cfg, jax.random.PRNGKey(0))
    engine = ServingEngine(cfg, params, max_batch=8, max_seq=32,
                           impl=Impl(attention="naive", remat=False))
    svc = EngineService(engine, timeout=120.0).start()
    gw = ServiceGateway("mpklink_opt", max_keys=512,
                        transport_kwargs={"timeout": 120.0})
    gw.register_service("infer", svc.handler, batch_handler=svc.handler_batch)
    gw.start()
    gw.enable_coalescing(max_batch=8, max_wait_us=50000.0)
    try:
        warm = gw.connect("warm")
        warm.open("infer")
        warm.call("infer", encode_prompt([1, 2], max_new=2))    # jit warmup

        n = 5
        clients = [gw.connect(f"inf-{i}") for i in range(n)]
        for c in clients:
            c.open("infer")
        outs: dict = {}
        errs: list = []
        barrier = threading.Barrier(n)

        def worker(i):
            try:
                barrier.wait()
                outs[i] = clients[i].call(
                    "infer", encode_prompt([1 + i, 2, 3], max_new=3))
            except Exception as e:
                errs.append(e)

        ts = [threading.Thread(target=worker, args=(i,)) for i in range(n)]
        for t in ts:
            t.start()
        for t in ts:
            t.join(WALL_BUDGET)
        assert not errs, errs[:2]
        assert all(np.asarray(outs[i]).size == 3 for i in range(n))
        assert any(c > 1 for c in svc.cohorts), \
            f"no multi-request cohort reached the engine: {svc.cohorts}"
    finally:
        gw.close()
        svc.close()


# ---------------------------------------------------------------------------
# chaos: the 8 FaultPlan kinds against auto-coalesced inline calls
# ---------------------------------------------------------------------------

def test_chaos_all_kinds_through_the_coalescer():
    """Full-kind FaultPlan with the mux on and concurrent cohort traffic:
    injected security faults surface as their EXPECTED types (FaultyClient
    raises FaultLeak otherwise), liveness faults heal per item, background
    cohort-mates keep completing correctly, and the whole run is bounded."""
    gw, mux = _mux_gateway(timeout=0.4)
    plan = FaultPlan(seed=2026, n_requests=30, rate=0.25)
    fab = FaultFabric(plan).attach(gw)
    stop = threading.Event()
    bg_errors: list = []
    bg_done = {"n": 0}

    def background(i):
        c = gw.connect(f"bg-{i}")
        c.open("wordcount")
        j = 0
        while not stop.is_set():
            n = 3 + (i + j) % 6
            try:
                out = c.call("wordcount", make_text(n, seed=i * 997 + j))
                assert parse_count(out) == n
                bg_done["n"] += 1
            except (TransportError, AccessViolation,
                    framing.FrameError):
                c.heal("wordcount")     # typed: heal and keep hammering
            j += 1

    threads = [threading.Thread(target=background, args=(i,), daemon=True)
               for i in range(4)]
    for t in threads:
        t.start()
    fc = FaultyClient(gw.connect("chaos-co"), fab, "wordcount")
    t0 = time.perf_counter()
    try:
        for i in range(plan.n_requests):
            n = 4 + i % 9
            out = fc.step(make_text(n, seed=i))
            if out.status == "ok":
                assert parse_count(out.value) == n, \
                    f"wrong answer at {i} — replay: {plan.describe()}"
    finally:
        stop.set()
        wall = time.perf_counter() - t0
        for t in threads:
            t.join(10.0)
        fab.detach()
        gw.close()
    assert wall < WALL_BUDGET, f"hung? {wall}s — replay: {plan.describe()}"
    assert bg_done["n"] > 0, "background cohort traffic never completed"
    # every injected client-side fault surfaced as its EXPECTED type (the
    # server kinds may heal transparently through the mux — that is the
    # coalescer's liveness fallback doing its job)
    for o in fc.outcomes:
        if o.status == "fault" and o.kind in CLIENT_KINDS:
            assert isinstance(o.value, EXPECTED[o.kind]), \
                f"{o} — replay: {plan.describe()}"
        # nothing may escape the typed taxonomy
        if isinstance(o.value, BaseException):
            assert isinstance(o.value, (TransportError, AccessViolation,
                                        framing.FrameError)), \
                f"untyped escape {o} — replay: {plan.describe()}"


@pytest.mark.parametrize("kind", ["corrupt_mac", "truncate", "reorder_seq",
                                  "stale_replay", "forge_identity",
                                  "crash_handler", "drop_response",
                                  "delay_response"])
def test_chaos_single_kind_through_the_coalescer(kind):
    """Each fault kind alone, with the mux enabled: typed and bounded."""
    gw, mux = _mux_gateway(timeout=0.4)
    plan = FaultPlan(seed=hash(("co", kind)) & 0xFFFF, n_requests=12,
                     rate=0.25, kinds=(kind,))
    assert len(plan.events) >= 2
    fab = FaultFabric(plan).attach(gw)
    fc = FaultyClient(gw.connect("chaos-one"), fab, "wordcount")
    t0 = time.perf_counter()
    try:
        for i in range(plan.n_requests):
            n = 4 + i % 7
            out = fc.step(make_text(n, seed=i))
            if out.status == "ok":
                assert parse_count(out.value) == n
    finally:
        wall = time.perf_counter() - t0
        fab.detach()
        gw.close()
    assert wall < WALL_BUDGET, f"hung? — replay: {plan.describe()}"
    expected = EXPECTED[kind]
    for o in fc.outcomes:
        if o.kind != kind or o.status != "fault":
            continue
        if kind in CLIENT_KINDS:
            assert isinstance(o.value, expected), \
                f"{o} — replay: {plan.describe()}"
        elif expected is not None:
            # server kinds may heal transparently through the mux; when
            # they DO surface, the type must be the taxonomy's
            assert isinstance(o.value, (expected, TransportError)), \
                f"{o} — replay: {plan.describe()}"


def test_duplicate_tokens_in_one_envelope_execute_once_loop_path():
    """call_many with a repeated idempotency token: the second item must be
    answered from the dedup window, not re-executed (the sequential-item
    semantics, preserved across the two-pass scatter refactor)."""
    calls = []

    def counting(req):
        calls.append(np.asarray(req).copy())
        return wordcount_handler(req)

    gw = ServiceGateway("mpklink_opt", max_keys=512)
    gw.register_service("wc", counting)
    gw.start()
    try:
        c = gw.connect("dup")
        c.open("wc")
        [tok] = c.mint_tokens(1)
        p = make_text(5, seed=1)
        r1, r2 = c.call_many([("wc", p), ("wc", p)], tokens=[tok, tok])
        assert parse_count(r1) == parse_count(r2) == 5
        assert len(calls) == 1, "duplicate token re-executed the handler"
        assert gw.stats["deduped"] == 1
    finally:
        gw.close()


def test_duplicate_tokens_in_one_envelope_execute_once_batch_path():
    """Same contract when the service routes through a native
    batch_handler: the duplicate stays out of the cohort submission."""
    seen = []

    def batch_wc(payloads):
        seen.append(len(payloads))
        return [wordcount_handler(p) for p in payloads]

    gw = ServiceGateway("mpklink_opt", max_keys=512)
    gw.register_service("wc", wordcount_handler, batch_handler=batch_wc)
    gw.start()
    try:
        c = gw.connect("dup-b")
        c.open("wc")
        [tok] = c.mint_tokens(1)
        other = c.mint_tokens(1)[0]
        p, q = make_text(4, seed=1), make_text(6, seed=2)
        r1, r2, r3 = c.call_many([("wc", p), ("wc", q), ("wc", p)],
                                 tokens=[tok, other, tok])
        assert parse_count(r1) == parse_count(r3) == 4
        assert parse_count(r2) == 6
        assert seen == [2], f"cohort submitted {seen}, want the 2 unique"
    finally:
        gw.close()
