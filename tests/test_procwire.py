"""Process-backed transports: real multiprocessing services over POSIX
shared memory (shm_proc / mpklink_proc / mpklink_opt_proc) and the honest
loopback baselines (rest / sockrpc) — correctness, crash taxonomy with
REAL process kills, segment-lifecycle hygiene, and the satellite
regressions that the in-process fast path never exercised."""
import os
import signal
import socket
import subprocess
import sys
import time

import numpy as np
import pytest

from repro.core import (ALL_TRANSPORTS, BASELINE_TRANSPORTS, PROC_TRANSPORTS,
                        ServiceGateway, procwire)
from repro.core.transports import (CapacityError, HandlerCrash,
                                   ResponseTimeout, ServiceCrashed,
                                   TransportError, _recv_exact)
from repro.core.wordcount import make_text, parse_count, wordcount_handler

pytestmark = pytest.mark.proc       # forks real service children; the CI
                                    # fleet job runs + flake-checks these

NEW_TRANSPORTS = sorted(PROC_TRANSPORTS) + sorted(BASELINE_TRANSPORTS)
ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def _echo(req):
    return np.asarray(req, np.uint8)[::-1].copy()


def _leftover_segments():
    return [f for f in os.listdir("/dev/shm") if f.startswith("mpk_")]


# ---------------------------------------------------------------------------
# roundtrips: every new transport behind the exact same Session API
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("name", NEW_TRANSPORTS)
def test_roundtrip_sizes(name):
    tr = ALL_TRANSPORTS[name](_echo, timeout=15.0)
    try:
        s = tr.connect()
        for nbytes in (1, 777, 65536):
            p = np.frombuffer(os.urandom(nbytes), np.uint8)
            out = s.request(p)
            assert bytes(out) == bytes(p[::-1]), (name, nbytes)
        s.close()
    finally:
        tr.close()


@pytest.mark.parametrize("name", NEW_TRANSPORTS)
def test_call_batch_larger_than_ring(name):
    """Pipelined batches wider than the slot ring run in windows; the
    lockstep baselines buffer — either way order and content hold."""
    tr = ALL_TRANSPORTS[name](_echo, timeout=15.0)
    try:
        s = tr.connect()
        payloads = [np.frombuffer(os.urandom(100 + 13 * i), np.uint8)
                    for i in range(20)]            # 20 > DEFAULT_RING_SLOTS
        outs = s.call_batch(payloads)
        assert len(outs) == 20
        for p, o in zip(payloads, outs):
            assert bytes(o) == bytes(p[::-1])
        s.close()
    finally:
        tr.close()


@pytest.mark.parametrize("name", sorted(PROC_TRANSPORTS))
def test_concurrent_sessions_are_isolated(name):
    """N sessions = N service processes with private segments/domains."""
    tr = ALL_TRANSPORTS[name](_echo, timeout=15.0)
    try:
        sessions = [tr.connect(f"c{i}") for i in range(3)]
        for rep in range(3):
            for i, s in enumerate(sessions):
                p = np.frombuffer(os.urandom(512 + 64 * i + rep), np.uint8)
                assert bytes(s.request(p)) == bytes(p[::-1])
        pids = {s._proc.pid for s in sessions if s._proc is not None}
        assert len(pids) == 3                       # three real processes
        for s in sessions:
            s.close()
    finally:
        tr.close()


def test_mpklink_proc_sync_schedule():
    """The paper's cost model survives the process boundary: mpklink pays
    ceil(frame/chunk) client syncs per publish + one service sync per
    drain pass; mpklink_opt pays exactly one of each."""
    p = np.frombuffer(os.urandom(200 * 1024), np.uint8)
    tr = ALL_TRANSPORTS["mpklink_proc"](_echo, timeout=15.0,
                                        capacity=256 * 1024)
    try:
        s = tr.connect()
        before = s.sync_count
        s.request(p)
        # frame = 200KiB payload + header -> 4 x 64KiB chunks + 1 svc sync
        assert s.sync_count - before == 5
        s.close()
    finally:
        tr.close()
    tr = ALL_TRANSPORTS["mpklink_opt_proc"](_echo, timeout=15.0,
                                            capacity=256 * 1024)
    try:
        s = tr.connect()
        before = s.sync_count
        s.request(p)
        assert s.sync_count - before == 2           # 1 publish + 1 drain
        s.close()
    finally:
        tr.close()


def test_mpklink_proc_request_into_zero_copy():
    """request_into writes the message straight into the SHARED segment."""
    tr = ALL_TRANSPORTS["mpklink_opt_proc"](_echo, timeout=15.0)
    try:
        s = tr.connect()
        src = np.frombuffer(os.urandom(4096), np.uint8)

        def fill(dst):
            assert dst.nbytes == 4096
            dst[:] = src
        out = s.request_into(4096, fill)
        assert bytes(out) == bytes(src[::-1])
        s.close()
    finally:
        tr.close()


# ---------------------------------------------------------------------------
# typed errors across the boundary
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("name", sorted(PROC_TRANSPORTS))
def test_oversized_request_is_capacity_error(name):
    tr = ALL_TRANSPORTS[name](_echo, timeout=15.0, capacity=64 * 1024)
    try:
        s = tr.connect()
        with pytest.raises(CapacityError):
            s.request(np.zeros(128 * 1024, np.uint8))
        # the session survives a refused oversized request
        p = np.frombuffer(os.urandom(100), np.uint8)
        assert bytes(s.request(p)) == bytes(p[::-1])
        s.close()
    finally:
        tr.close()


@pytest.mark.parametrize("name", sorted(PROC_TRANSPORTS))
def test_oversized_response_is_typed_not_stranded(name):
    """A handler reply bigger than the response area must surface to the
    CALLER as CapacityError (marshalled from the child), never wedge."""
    def grow(req):
        return np.zeros(256 * 1024, np.uint8)

    tr = ALL_TRANSPORTS[name](grow, timeout=15.0, capacity=32 * 1024)
    try:
        s = tr.connect()
        with pytest.raises(CapacityError):
            s.request(np.zeros(16, np.uint8))
        s.close()
    finally:
        tr.close()


@pytest.mark.parametrize("name", NEW_TRANSPORTS)
def test_handler_exception_marshals_typed(name):
    def angry(req):
        raise ValueError("wrong shape")

    tr = ALL_TRANSPORTS[name](angry, timeout=15.0)
    try:
        s = tr.connect()
        with pytest.raises(TransportError, match="wrong shape"):
            s.request(np.zeros(8, np.uint8))
        s.close()
    finally:
        tr.close()


@pytest.mark.parametrize("name", sorted(PROC_TRANSPORTS) + ["sockrpc"])
def test_slow_handler_is_response_timeout_not_crash(name):
    def slow(req):
        time.sleep(1.0)
        return np.asarray(req)

    tr = ALL_TRANSPORTS[name](slow, timeout=0.15)
    try:
        s = tr.connect()
        with pytest.raises(ResponseTimeout):
            s.request(np.zeros(8, np.uint8))
        with pytest.raises(TransportError, match="poisoned"):
            s.request(np.zeros(8, np.uint8))
        s.close()
    finally:
        tr.close()


# ---------------------------------------------------------------------------
# REAL process crashes: kill -9 semantics, typed + immediate
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("name", NEW_TRANSPORTS)
def test_handler_crash_kills_real_process_typed_and_fast(name):
    """HandlerCrash SIGKILLs the service PROCESS; the client sees typed
    ServiceCrashed within the doorbell-EOF window, never a deadline."""
    def die(req):
        raise HandlerCrash("chaos")

    tr = ALL_TRANSPORTS[name](die, timeout=30.0)
    try:
        s = tr.connect()
        t0 = time.perf_counter()
        with pytest.raises(ServiceCrashed):
            s.request(np.zeros(8, np.uint8))
        assert time.perf_counter() - t0 < 5.0, "sat out the deadline"
        if name in PROC_TRANSPORTS:
            s._proc.join(timeout=2.0)
            assert s._proc.exitcode == -signal.SIGKILL   # a real kill -9
            # a dead session refuses new work immediately, typed
            with pytest.raises(ServiceCrashed):
                s.submit(np.zeros(8, np.uint8))
        s.close()
    finally:
        tr.close()


def test_external_sigkill_with_request_in_flight_surfaces_immediately():
    """kill -9 from OUTSIDE with a request in flight: doorbell EOF turns
    the kill into ServiceCrashed within the wait slice — the client never
    sits out its (long) 30s deadline on a dead service."""
    def slow(req):
        time.sleep(5.0)
        return np.asarray(req)

    tr = ALL_TRANSPORTS["mpklink_opt_proc"](slow, timeout=30.0)
    try:
        s = tr.connect()
        t = s.submit(np.zeros(8, np.uint8))
        s.flush()                        # child is now serving (slowly)
        time.sleep(0.2)
        assert s._proc is not None and s._proc.is_alive()
        os.kill(s._proc.pid, signal.SIGKILL)
        t0 = time.perf_counter()
        with pytest.raises(ServiceCrashed):
            s.poll(t)
        assert time.perf_counter() - t0 < 5.0
        s.close()
    finally:
        tr.close()


def test_crash_while_holding_sealed_slot_never_recycles():
    """Satellite: a slot the dead service had live (published, being
    served) must never return to the arena — a fresh message must not
    alias rows of unknown provenance. The whole segment dies with the
    session instead."""
    def die_second(req):
        if req[0] == 2:
            raise HandlerCrash("mid-drain death")
        return np.asarray(req, np.uint8).copy()

    tr = ALL_TRANSPORTS["mpklink_opt_proc"](die_second, timeout=5.0)
    try:
        s = tr.connect()
        first = np.full(64, 1, np.uint8)
        assert bytes(s.request(first)) == bytes(first)
        doomed = np.full(64, 2, np.uint8)
        t = s.submit(doomed)
        s.flush()
        with pytest.raises(ServiceCrashed):
            s.poll(t)
        # the crashed ticket's slot + arena buffers stay pinned forever
        assert t in s._inflight
        slot = s._slots[t % s._nslots]
        assert int(slot[procwire._S_STATE]) != procwire._FREE
        free_lists = s.arena._free
        req_buf, resp_buf, _ = s._inflight[t]
        for lst in free_lists.values():
            for buf in lst:
                assert buf.ctypes.data != req_buf.ctypes.data
                assert buf.ctypes.data != resp_buf.ctypes.data
        # and the session refuses new submissions outright
        with pytest.raises(ServiceCrashed):
            s.submit(np.zeros(8, np.uint8))
        name = s._seg.name
        s.close()
        assert not os.path.exists(f"/dev/shm/{name}")   # segment unlinked
    finally:
        tr.close()


# ---------------------------------------------------------------------------
# satellite: _recv_exact peer-death taxonomy (socket transports == rings)
# ---------------------------------------------------------------------------

def test_recv_exact_eof_is_service_crashed():
    """Unit: a peer closing mid-message is liveness (ServiceCrashed), not
    a generic protocol error — pre-fix code raised bare TransportError."""
    a, b = socket.socketpair()
    try:
        a.sendall(b"ab")                 # partial: 2 of 4 requested bytes
        a.close()
        with pytest.raises(ServiceCrashed):
            _recv_exact(b, 4)
    finally:
        b.close()


def test_sockrpc_killed_server_is_service_crashed():
    """End-to-end: kill -9 the TCP RPC server mid-session; the client's
    _recv_exact EOF classifies exactly like a dead ring service."""
    tr = ALL_TRANSPORTS["sockrpc"](_echo, timeout=10.0)
    try:
        s = tr.connect()
        p = np.frombuffer(os.urandom(64), np.uint8)
        assert bytes(s.request(p)) == bytes(p[::-1])
        tr.kill_server()
        with pytest.raises(ServiceCrashed):
            s.request(p)
        # the transport respawns its server; a fresh attempt succeeds
        assert bytes(s.request(p)) == bytes(p[::-1])
        s.close()
    finally:
        tr.close()


def test_rest_killed_server_is_service_crashed():
    tr = ALL_TRANSPORTS["rest"](_echo, timeout=10.0)
    try:
        s = tr.connect()
        p = np.frombuffer(os.urandom(64), np.uint8)
        assert bytes(s.request(p)) == bytes(p[::-1])
        tr.kill_server()
        with pytest.raises(ServiceCrashed):
            s.request(p)
        assert bytes(s.request(p)) == bytes(p[::-1])
        s.close()
    finally:
        tr.close()


def test_rest_is_actually_http():
    """The REST baseline must speak real HTTP/1.1 + JSON on a real TCP
    port — not a framed socketpair in disguise."""
    import base64
    import http.client
    import json
    tr = ALL_TRANSPORTS["rest"](_echo, timeout=10.0)
    try:
        s = tr.connect()
        p = np.arange(16, dtype=np.uint8)
        s.request(p)                     # forks the server
        conn = http.client.HTTPConnection("127.0.0.1", tr.port, timeout=5.0)
        conn.request("POST", "/invoke",
                     body=json.dumps({"payload": base64.b64encode(
                         p.tobytes()).decode("ascii")}),
                     headers={"Content-Type": "application/json"})
        r = conn.getresponse()
        assert r.version == 11 and r.status == 200
        doc = json.loads(r.read())
        assert base64.b64decode(doc["result"]) == p.tobytes()[::-1]
        conn.close()
        s.close()
    finally:
        tr.close()


# ---------------------------------------------------------------------------
# satellite: credit wait clamped by the caller's budget (proc twin)
# ---------------------------------------------------------------------------

def test_proc_submit_timeout_clamps_credit_wait():
    """A full ring + submit(timeout=0.05) surfaces ResponseTimeout in
    ~0.05s even with a 30s credit window; the credit window alone still
    yields CapacityError."""
    def slow(req):
        time.sleep(0.6)
        return np.asarray(req)

    tr = ALL_TRANSPORTS["shm_proc"](slow, timeout=30.0, ring_slots=2,
                                    credit_wait=30.0)
    try:
        s = tr.connect()
        for _ in range(2):               # fill both slots
            s.submit(np.zeros(8, np.uint8))
        s.flush()
        t0 = time.perf_counter()
        with pytest.raises(ResponseTimeout):
            s.submit(np.zeros(8, np.uint8), timeout=0.05)
        assert time.perf_counter() - t0 < 1.0
        s.close()
    finally:
        tr.close()
    tr = ALL_TRANSPORTS["shm_proc"](slow, timeout=30.0, ring_slots=2,
                                    credit_wait=0.08)
    try:
        s = tr.connect()
        for _ in range(2):
            s.submit(np.zeros(8, np.uint8))
        s.flush()
        t0 = time.perf_counter()
        with pytest.raises(CapacityError):
            s.submit(np.zeros(8, np.uint8))
        assert time.perf_counter() - t0 < 1.0
        s.close()
    finally:
        tr.close()


# ---------------------------------------------------------------------------
# satellite: segment lifecycle — idempotent close-with-unlink, no leaks
# ---------------------------------------------------------------------------

def test_close_is_idempotent_and_unlinks():
    tr = ALL_TRANSPORTS["mpklink_opt_proc"](_echo, timeout=10.0)
    try:
        s = tr.connect()
        p = np.frombuffer(os.urandom(256), np.uint8)
        s.request(p)
        name = s._seg.name
        assert os.path.exists(f"/dev/shm/{name}")
        s.close()
        assert not os.path.exists(f"/dev/shm/{name}")
        s.close()                        # second close: clean no-op
        s.close()
    finally:
        tr.close()


def test_close_with_live_response_view_still_unlinks():
    """A caller-held response view pins the MAPPING (close defers) but
    must never pin the NAME: unlink happens at close regardless."""
    tr = ALL_TRANSPORTS["shm_proc"](_echo, timeout=10.0)
    try:
        s = tr.connect()
        p = np.frombuffer(os.urandom(256), np.uint8)
        out = s.request(p)               # view aliases the shared slab
        name = s._seg.name
        s.close()
        assert not os.path.exists(f"/dev/shm/{name}")
        assert bytes(out) == bytes(p[::-1])     # view stays readable
        del out
    finally:
        tr.close()


def test_no_segment_or_tracker_leaks_100_cycles():
    """Satellite acceptance: 100 open/close cycles in a fresh interpreter
    — zero resource_tracker warnings, zero stderr noise, zero /dev/shm
    leftovers (including one deliberately UNCLOSED session covered by
    the finalizer backstop)."""
    script = r"""
import os, numpy as np
from repro.core import ALL_TRANSPORTS

def echo(req):
    return np.asarray(req, np.uint8).copy()

for i in range(100):
    name = ("shm_proc", "mpklink_opt_proc")[i % 2]
    tr = ALL_TRANSPORTS[name](echo, timeout=10.0)
    s = tr.connect()
    s.request(np.zeros(64, np.uint8))
    s.close()
    tr.close()
# one sloppy user: session never closed — the finalizer backstop unlinks
tr = ALL_TRANSPORTS["shm_proc"](echo, timeout=10.0)
s = tr.connect()
s.request(np.zeros(64, np.uint8))
print("CYCLES-DONE", len([f for f in os.listdir('/dev/shm')
                          if f.startswith('mpk_')]))
"""
    env = dict(os.environ, PYTHONPATH=os.path.join(ROOT, "src"))
    r = subprocess.run([sys.executable, "-c", script], env=env,
                       capture_output=True, text=True, timeout=300)
    assert r.returncode == 0, r.stderr
    # while running, exactly ONE segment may be live (the unclosed one)
    assert "CYCLES-DONE 1" in r.stdout, r.stdout
    assert "resource_tracker" not in r.stderr, r.stderr
    assert "BufferError" not in r.stderr, r.stderr
    assert "Traceback" not in r.stderr, r.stderr
    assert _leftover_segments() == []    # backstop unlinked the stray


# ---------------------------------------------------------------------------
# gateway integration: named services over process-backed transports
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("name", sorted(PROC_TRANSPORTS) + ["sockrpc"])
def test_gateway_over_process_transport(name):
    gw = ServiceGateway(name, transport_kwargs={"timeout": 20.0})
    gw.register_service("wordcount", wordcount_handler)
    gw.start()
    try:
        c = gw.connect("cli")
        for i in range(4):
            n = 5 + i
            assert parse_count(c.call("wordcount",
                                      make_text(n, seed=i))) == n
    finally:
        gw.close()


def test_gateway_heals_killed_service_process():
    """The full process-crash recovery story: a crashing handler SIGKILLs
    the service child (typed ServiceCrashed); the PARENT restarts the
    service (factory swap + epoch bump — a fork snapshot can't see live
    control-plane changes, §6); a retrying client's heal then forks a
    FRESH child whose snapshot carries the restarted handler AND the new
    epoch."""
    def flaky(req):
        raise HandlerCrash("die")

    gw = ServiceGateway("mpklink_opt_proc",
                        transport_kwargs={"timeout": 20.0})
    gw.register_service("wc", flaky, factory=lambda: wordcount_handler)
    gw.start()
    try:
        c = gw.connect("cli", retries=2)
        with pytest.raises(ServiceCrashed):
            c.call("wc", make_text(6, seed=0))     # every re-fork still dies
        gw.restart_service("wc")                   # operator/supervisor heal
        assert parse_count(c.call("wc", make_text(6, seed=1))) == 6
    finally:
        gw.close()
