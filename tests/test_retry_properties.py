"""Retry-budget and hedging properties (ISSUE 9 / docs/protocol.md §9):
under every FaultPlan kind — and real kill -9 — total attempts
(primary + liveness retries + hedges) never exceed the token-bucket
budget, no request double-executes, and identical seeds produce
identical outcome sequences AND identical budget spend.

The fault-matrix properties are in-process and tier-1; the kill -9
property forks real replica children and is marked ``proc``."""
import os
import signal
import threading
import time
import zlib

import numpy as np
import pytest

from repro.core import ServiceGateway
from repro.core.faultwire import (ALL_KINDS, FaultFabric, FaultPlan,
                                  FaultyClient)
from repro.core.gateway import REPLICA_ACTIVE, RetryBudget
from repro.core.wordcount import make_text, parse_count, wordcount_handler

TIMEOUT = 0.4
WALL_BUDGET = 60.0
_PROC_KW = {"ring_slots": 2, "timeout": 30.0}


def _counting_gateway():
    """Gateway whose wordcount handler counts executions PER PAYLOAD —
    the ground truth for the no-double-execution property."""
    counts = {}
    lock = threading.Lock()

    def counting(req):
        key = bytes(np.asarray(req, np.uint8).tobytes())
        with lock:
            counts[key] = counts.get(key, 0) + 1
        return wordcount_handler(req)

    gw = ServiceGateway("mpklink_opt", transport_kwargs={"timeout": TIMEOUT})
    gw.register_service("wordcount", counting, factory=lambda: counting)
    return gw.start(), counts


def _run_plan(plan, *, retries=3, budget=None):
    gw, counts = _counting_gateway()
    fab = FaultFabric(plan).attach(gw)
    fc = FaultyClient(gw.connect("prop-client", retries=retries,
                                 retry_budget=budget), fab, "wordcount")
    t0 = time.perf_counter()
    try:
        for i in range(plan.n_requests):
            n = 4 + i % 9
            out = fc.step(make_text(n, seed=i))
            if out.status == "ok":
                assert parse_count(out.value) == n, \
                    f"wrong answer at {i} — replay: {plan.describe()}"
    finally:
        wall = time.perf_counter() - t0
        gw.close()
    sig = [(o.index, o.status, o.kind, type(o.value).__name__)
           for o in fc.outcomes]
    return sig, wall, counts, fc


# ---------------------------------------------------------------------------
# the two core properties, per fault kind
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("kind", ALL_KINDS)
def test_budget_and_single_execution_per_kind(kind):
    """For every fault kind: (1) no payload ever executes more than once
    — dedup answers retried duplicates from the window; (2) extra
    attempts stay within the token bucket's mathematical bound
    ``initial + ratio × primaries``; (3) the run is wall-bounded."""
    # NOT hash(): builtin hash is salted per process (PYTHONHASHSEED), and
    # an unlucky plan can drift a server-side drop onto a non-faulted wire
    # index once retries shift the schedule — the seed must be stable
    plan = FaultPlan(seed=(zlib.crc32(kind.encode()) + 3) & 0xFFFF,
                     n_requests=24, rate=0.25, kinds=(kind,))
    assert len(plan.events) >= 2
    budget = RetryBudget(ratio=0.25, burst=3)
    sig, wall, counts, fc = _run_plan(plan, budget=budget)
    assert wall < WALL_BUDGET, f"hung? — replay: {plan.describe()}"
    over = {k: v for k, v in counts.items() if v > 1}
    assert not over, \
        f"double-executed under {kind}: {len(over)} payloads — " \
        f"replay: {plan.describe()}"
    allowance = 3 + 0.25 * plan.n_requests
    assert budget.spent <= allowance, (budget.spent, allowance)
    assert fc.counts()["error"] == 0, f"replay: {plan.describe()}"


def test_budget_and_single_execution_full_matrix():
    """All 8 kinds interleaved in one seeded run — the properties hold
    jointly, not just per-kind."""
    plan = FaultPlan(seed=0x90B, n_requests=48, rate=0.3)
    budget = RetryBudget(ratio=0.25, burst=3)
    sig, wall, counts, fc = _run_plan(plan, budget=budget)
    assert wall < WALL_BUDGET
    assert all(v <= 1 for v in counts.values()), \
        f"replay: {plan.describe()}"
    assert budget.spent <= 3 + 0.25 * plan.n_requests
    assert fc.counts()["error"] == 0, f"replay: {plan.describe()}"


def test_dry_budget_means_zero_extra_attempts():
    """With an empty bucket the client may not retry at all, whatever
    ``retries`` says: executions ≤ primaries, spend stays zero, and the
    refusals are counted."""
    plan = FaultPlan(seed=0xD0, n_requests=24, rate=0.3,
                     kinds=("drop_response", "crash_handler"))
    budget = RetryBudget(ratio=0.0, burst=1, initial=0.0)
    sig, wall, counts, fc = _run_plan(plan, budget=budget)
    assert budget.spent == 0
    assert budget.denied >= 1
    assert sum(counts.values()) <= plan.n_requests
    assert all(v <= 1 for v in counts.values())


def test_identical_seed_identical_outcomes_and_spend():
    """Seeded determinism extends to the budget: two runs of the same
    plan fingerprint identically AND spend identically."""
    spec = FaultPlan(seed=424, n_requests=30, rate=0.3).spec()
    b1 = RetryBudget(ratio=0.25, burst=3)
    b2 = RetryBudget(ratio=0.25, burst=3)
    sig1, _, _, _ = _run_plan(FaultPlan.from_spec(spec), budget=b1)
    sig2, _, _, _ = _run_plan(FaultPlan.from_spec(spec), budget=b2)
    assert sig1 == sig2
    assert (b1.spent, b1.denied) == (b2.spent, b2.denied)


# ---------------------------------------------------------------------------
# hedging: late binding — one wire send ever, budget-capped
# ---------------------------------------------------------------------------

def _tagged_counting(i, counts, lock):
    def handler(req):
        with lock:
            counts[i] = counts.get(i, 0) + 1
        return np.concatenate([np.asarray(req, np.uint8),
                               np.array([i], np.uint8)])
    return handler


def _hedge_fleet(n=2):
    counts, lock = {}, threading.Lock()
    gw = ServiceGateway("mpklink_opt")
    for i in range(n):
        gw.register_replica("echo", _tagged_counting(i, counts, lock),
                            transport="mpklink_opt")
    return gw.start(), counts


def test_hedge_fires_once_and_executes_once():
    """Both replicas' wire locks held → the parked request hedges to the
    other replica after the delay, completes there when released, and the
    handler population executed EXACTLY once (late binding: the hedge
    re-routes before any send)."""
    gw, counts = _hedge_fleet(2)
    fleet = gw.fleet("echo")
    budget = fleet.enable_hedging(delay=0.05)
    try:
        for rep in fleet._replicas.values():
            assert rep.rlock.acquire(timeout=1.0)
        cli = gw.connect("c0")
        result = {}

        def caller():
            result["out"] = cli.call("echo", np.arange(4, dtype=np.uint8))

        t = threading.Thread(target=caller)
        t.start()
        time.sleep(0.4)                 # well past the hedge delay
        assert fleet.stats["hedges_fired"] == 1
        for rep in fleet._replicas.values():
            rep.rlock.release()
        t.join(timeout=10)
        assert np.asarray(result["out"])[:4].tolist() == [0, 1, 2, 3]
        assert sum(counts.values()) == 1
        assert fleet.stats["hedges_won"] == 1
        assert budget.spent == 1
        cli.close()
    finally:
        for rep in fleet._replicas.values():
            try:
                rep.rlock.release()
            except RuntimeError:
                pass
        gw.close()


def test_hedge_respects_dry_budget():
    """Bucket empty → the parked request waits like an unhedged one;
    zero hedges fire and the refusal is counted."""
    gw, counts = _hedge_fleet(2)
    fleet = gw.fleet("echo")
    budget = fleet.enable_hedging(
        delay=0.05, budget=RetryBudget(ratio=0.0, burst=1, initial=0.0))
    try:
        for rep in fleet._replicas.values():
            assert rep.rlock.acquire(timeout=1.0)
        cli = gw.connect("c0")
        result = {}

        def caller():
            result["out"] = cli.call("echo", np.arange(4, dtype=np.uint8))

        t = threading.Thread(target=caller)
        t.start()
        time.sleep(0.4)
        assert fleet.stats["hedges_fired"] == 0
        assert budget.denied >= 1
        for rep in fleet._replicas.values():
            rep.rlock.release()
        t.join(timeout=10)
        assert np.asarray(result["out"])[:4].tolist() == [0, 1, 2, 3]
        assert sum(counts.values()) == 1
        cli.close()
    finally:
        for rep in fleet._replicas.values():
            try:
                rep.rlock.release()
            except RuntimeError:
                pass
        gw.close()


def test_hedge_load_single_execution_per_request():
    """Concurrent clients against slow replicas with hedging on: every
    request executes exactly once fleet-wide (sum of handler executions
    == completed requests) and hedge spend stays within the bucket."""
    counts, lock = {}, threading.Lock()

    def slow_counting(i):
        def handler(req):
            with lock:
                counts[bytes(np.asarray(req, np.uint8).tobytes())] = \
                    counts.get(bytes(np.asarray(req, np.uint8).tobytes()),
                               0) + 1
            time.sleep(0.02)
            return np.asarray(req, np.uint8)
        return handler

    gw = ServiceGateway("mpklink_opt")
    for i in range(2):
        gw.register_replica("echo", slow_counting(i),
                            transport="mpklink_opt")
    gw.start()
    fleet = gw.fleet("echo")
    budget = fleet.enable_hedging(delay=0.01,
                                  budget=RetryBudget(ratio=1.0, burst=64,
                                                     initial=64))
    try:
        n_clients, reps = 6, 5
        errors = []

        def worker(i):
            try:
                c = gw.connect(f"c{i}")
                for j in range(reps):
                    payload = np.array([i, j, i + j], np.uint8)
                    out = c.call("echo", payload)
                    np.testing.assert_array_equal(np.asarray(out), payload)
                c.close()
            except Exception as e:      # pragma: no cover - surfaced below
                errors.append((i, repr(e)))

        threads = [threading.Thread(target=worker, args=(i,))
                   for i in range(n_clients)]
        for t in threads:
            t.start()
        for t in threads:
            t.join(timeout=60)
        assert not errors, errors
        assert all(v == 1 for v in counts.values()), \
            {k: v for k, v in counts.items() if v > 1}
        assert len(counts) == n_clients * reps
        assert budget.spent == fleet.stats["hedges_fired"]
        assert budget.spent <= 64
    finally:
        gw.close()


# ---------------------------------------------------------------------------
# proc: the properties under real kill -9 (CI fleet job)
# ---------------------------------------------------------------------------

@pytest.mark.proc
def test_kill9_no_lost_no_double_budget_bounded():
    """kill -9 a live replica mid-traffic: every request either succeeds
    (correct answer) or fails TYPED; each success executed on exactly one
    replica (sum of served == successes); client retry spend stays within
    the bucket."""
    def tagged(i):
        def handler(req):
            return np.concatenate([np.asarray(req, np.uint8),
                                   np.array([i], np.uint8)])
        return handler

    gw = ServiceGateway("mpklink_opt")
    for i in range(2):
        gw.register_replica("echo", tagged(i), transport_kwargs=_PROC_KW)
    gw.start()
    fleet = gw.fleet("echo")
    budget = RetryBudget(ratio=0.25, burst=3)
    try:
        cli = gw.connect("c0", retries=3, retry_budget=budget)
        warm = 0
        while not all(r.session._proc is not None
                      for r in fleet._replicas.values()):
            cli.call("echo", np.arange(4, dtype=np.uint8))
            warm += 1
            assert warm < 100, "fleet never warmed"
        victim = next(r for r in fleet._replicas.values()
                      if r.session._proc is not None)
        os.kill(victim.session._proc.pid, signal.SIGKILL)
        ok = 0
        n = 40
        for k in range(n):
            try:
                out = cli.call("echo", np.arange(4, dtype=np.uint8))
            except Exception as e:
                # typed liveness failure only — never silence, never hang
                from repro.core.transports import TransportError
                assert isinstance(e, TransportError), repr(e)
            else:
                assert np.asarray(out)[:4].tolist() == [0, 1, 2, 3]
                ok += 1
        served = sum(r.served for r in fleet._replicas.values())
        assert served == warm + ok, (served, warm, ok)
        assert budget.spent <= 3 + 0.25 * (warm + n)
        assert ok >= n // 2, f"only {ok}/{n} healed"
        cli.close()
    finally:
        gw.close()
