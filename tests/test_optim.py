"""AdamW, schedules, clipping, gradient compression."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import OptimizerConfig
from repro.optim import (adamw_update, clip_by_global_norm, cosine_lr,
                         dequantize_int8, global_norm, init_opt_state,
                         quantize_int8)


def test_adamw_converges_quadratic():
    p = {"w": jnp.array([3.0, -2.0]), "b": jnp.ones((2, 2))}
    st = init_opt_state(p)
    cfg = OptimizerConfig(lr=0.1, warmup_steps=5, total_steps=200,
                          weight_decay=0.0, grad_clip=10.0)
    for _ in range(200):
        g = jax.tree.map(lambda x: 2 * x, p)
        p, st, m = adamw_update(p, g, st, cfg)
    assert all(float(jnp.max(jnp.abs(x))) < 0.05 for x in jax.tree.leaves(p))
    assert int(st["step"]) == 200


def test_weight_decay_skips_1d():
    p = {"mat": jnp.ones((2, 2)), "vec": jnp.ones((4,))}
    st = init_opt_state(p)
    cfg = OptimizerConfig(lr=0.1, warmup_steps=0, total_steps=10,
                          weight_decay=1.0, grad_clip=1e9)
    zero_g = jax.tree.map(jnp.zeros_like, p)
    p2, _, _ = adamw_update(p, zero_g, st, cfg)
    assert float(jnp.max(jnp.abs(p2["vec"] - 1.0))) < 1e-6    # no decay
    assert float(jnp.max(p2["mat"])) < 1.0                     # decayed


def test_cosine_schedule_shape():
    cfg = OptimizerConfig(lr=1.0, warmup_steps=10, total_steps=100,
                          min_lr_ratio=0.1)
    assert float(cosine_lr(jnp.int32(0), cfg)) == 0.0
    assert abs(float(cosine_lr(jnp.int32(10), cfg)) - 1.0) < 1e-6
    assert abs(float(cosine_lr(jnp.int32(100), cfg)) - 0.1) < 1e-6
    assert float(cosine_lr(jnp.int32(55), cfg)) > 0.1


def test_clip_by_global_norm():
    g = {"a": jnp.full((4,), 10.0)}
    clipped, norm = clip_by_global_norm(g, 1.0)
    assert abs(float(norm) - 20.0) < 1e-4
    assert abs(float(global_norm(clipped)) - 1.0) < 1e-4
    g2 = {"a": jnp.full((4,), 0.01)}
    same, _ = clip_by_global_norm(g2, 1.0)
    np.testing.assert_allclose(same["a"], g2["a"])


def test_quantize_roundtrip_bound():
    x = jax.random.normal(jax.random.PRNGKey(0), (4096,))
    q, s = quantize_int8(x)
    err = jnp.max(jnp.abs(dequantize_int8(q, s) - x))
    assert float(err) <= float(s) * 0.5 + 1e-7


def test_bf16_moment_dtype():
    p = {"w": jnp.ones((4, 4))}
    st = init_opt_state(p, jnp.bfloat16)
    assert st["m"]["w"].dtype == jnp.bfloat16
    cfg = OptimizerConfig(lr=0.01, warmup_steps=0, total_steps=10)
    p2, st2, _ = adamw_update(p, jax.tree.map(jnp.ones_like, p), st, cfg)
    assert st2["m"]["w"].dtype == jnp.bfloat16
    assert p2["w"].dtype == p["w"].dtype


def test_compressed_reduce_multidevice():
    """int8+EF all-reduce across 8 fake devices (subprocess)."""
    import subprocess, sys, os
    code = """
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import jax, jax.numpy as jnp, numpy as np
from jax.sharding import PartitionSpec as P
from repro.utils import shard_map
from repro.optim import compressed_reduce

mesh = jax.make_mesh((8,), ("pod",))
g = jax.random.normal(jax.random.PRNGKey(0), (8, 16, 4))

def f(gl, ef):
    out, new_ef = compressed_reduce(gl[0], ef[0], "pod")
    return out[None], new_ef[None]

ef0 = jnp.zeros((8, 2, 4))
out, ef = jax.jit(shard_map(f, mesh=mesh, in_specs=(P("pod"), P("pod")),
                            out_specs=(P("pod"), P("pod"))))(g, ef0)
exact = np.asarray(g).mean(0)
for d in range(8):
    got = np.asarray(out[d])
    # int8 quantization error bounded by ~scale
    assert np.abs(got - exact).max() < np.abs(exact).max() / 50, d
# error feedback captures the residual
assert np.abs(np.asarray(ef)).max() > 0
print("OK")
"""
    env = dict(os.environ, PYTHONPATH="src")
    r = subprocess.run([sys.executable, "-c", code], capture_output=True,
                       text=True, cwd=os.path.join(os.path.dirname(__file__), ".."),
                       env=env, timeout=300)
    assert "OK" in r.stdout, r.stdout + r.stderr
