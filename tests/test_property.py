"""Property-based tests (hypothesis) on system invariants."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

hypothesis = pytest.importorskip(
    "hypothesis", reason="optional test dep; installed in CI")
from hypothesis import given, settings, strategies as st

from repro.core import framing
from repro.core.transports import fast_mac
from repro.core.wordcount import count_words, make_text
from repro.kernels.flash_jnp import flash_attention_jnp
from repro.kernels.ref import attention_ref, mac_ref
from repro.optim import dequantize_int8, quantize_int8

SET = settings(max_examples=25, deadline=None)


@given(st.integers(1, 2000), st.integers(0, 10_000))
@SET
def test_wordcount_exact(n, seed):
    assert int(count_words(make_text(n, seed=seed))[0]) == n


@given(st.lists(st.integers(-1000, 1000), min_size=1, max_size=300),
       st.integers(0, 2 ** 32 - 1), st.integers(0, 2 ** 31))
@SET
def test_frame_roundtrip_any_ints(data, seed, seq):
    arr = np.asarray(data, np.int32)
    frame = framing.build_frame(arr, seed=seed, seq=seq)
    out = framing.parse_frame(frame, seed=seed, expect_seq=seq)
    np.testing.assert_array_equal(out, arr)


@given(st.integers(1, 200), st.integers(0, 127), st.integers(0, 2 ** 32 - 1))
@SET
def test_mac_detects_any_single_flip(rows, lane, seed):
    rng = np.random.default_rng(seed % 1000)
    p = rng.integers(0, 2 ** 32, (rows, 128), dtype=np.uint64).astype(np.uint32)
    row = seed % rows
    m0 = fast_mac(p, seed)
    p2 = p.copy()
    p2[row, lane] ^= np.uint32(1 << (seed % 32))
    assert fast_mac(p2, seed) != m0


@given(st.integers(1, 400), st.integers(0, 10 ** 6))
@SET
def test_fast_mac_matches_scan_mac(rows, seed):
    rng = np.random.default_rng(seed)
    p = rng.integers(0, 2 ** 32, (rows, 128), dtype=np.uint64).astype(np.uint32)
    assert fast_mac(p, seed, block_rows=37) == framing._mac_np(p, seed)
    got = int(mac_ref(jnp.asarray(p), jnp.uint32(seed & 0xFFFFFFFF)))
    assert got == framing._mac_np(p, seed & 0xFFFFFFFF)


@given(st.integers(2, 64), st.integers(0, 10 ** 6))
@SET
def test_quantize_error_bounded(n, seed):
    x = np.random.default_rng(seed).standard_normal(n).astype(np.float32) * 10
    q, s = quantize_int8(jnp.asarray(x))
    err = np.abs(np.asarray(dequantize_int8(q, s)) - x).max()
    assert err <= float(s) * 0.5 + 1e-6


@given(st.integers(4, 24), st.integers(0, 10 ** 6))
@SET
def test_attention_causality(S, seed):
    """Output at position t is independent of tokens at positions > t."""
    ks = jax.random.split(jax.random.PRNGKey(seed % 2 ** 30), 4)
    B, H, Dh = 1, 2, 8
    q = jax.random.normal(ks[0], (B, S, H, Dh))
    k = jax.random.normal(ks[1], (B, S, H, Dh))
    v = jax.random.normal(ks[2], (B, S, H, Dh))
    pos = jnp.arange(S, dtype=jnp.int32)[None]
    out = flash_attention_jnp(q, k, v, pos, pos, causal=True, q_chunk=4, kv_chunk=4)
    t = S // 2
    k2 = k.at[:, t + 1:].set(jax.random.normal(ks[3], (B, S - t - 1, H, Dh)))
    v2 = v.at[:, t + 1:].set(0.5)
    out2 = flash_attention_jnp(q, k2, v2, pos, pos, causal=True, q_chunk=4, kv_chunk=4)
    np.testing.assert_allclose(out[:, :t + 1], out2[:, :t + 1], rtol=1e-5, atol=1e-5)


@given(st.integers(1, 30), st.integers(0, 10 ** 6))
@SET
def test_chunked_attention_matches_ref_random_shapes(S, seed):
    ks = jax.random.split(jax.random.PRNGKey(seed % 2 ** 30), 3)
    B, Hkv, g, Dh = 1, 2, 2, 4
    H = Hkv * g
    q = jax.random.normal(ks[0], (B, S, H, Dh))
    k = jax.random.normal(ks[1], (B, S, Hkv, Dh))
    v = jax.random.normal(ks[2], (B, S, Hkv, Dh))
    pos = jnp.arange(S, dtype=jnp.int32)[None]
    ref = attention_ref(q, k, v, pos, pos, causal=True)
    got = flash_attention_jnp(q, k, v, pos, pos, causal=True, q_chunk=8, kv_chunk=8)
    np.testing.assert_allclose(got, ref, rtol=3e-5, atol=3e-5)


@given(st.integers(0, 2 ** 31 - 1))
@SET
def test_signature_never_verifies_wrong_message(seed):
    from repro.core import signature as sig
    kp = sig.KeyPair.generate(f"svc{seed}")
    s = sig.sign(kp.private, b"m1")
    assert sig.verify(kp.public, b"m1", s)
    assert not sig.verify(kp.public, b"m2", s)


@given(st.integers(1, 64), st.integers(1, 8), st.integers(0, 10 ** 6))
@SET
def test_ssd_is_linear_in_x(S, P, seed):
    """The SSD recurrence is linear in x: f(αx) == αf(x) (with D term)."""
    from repro.kernels.ssd_jnp import ssd_chunked
    ks = jax.random.split(jax.random.PRNGKey(seed % 2 ** 30), 5)
    B, H, G, N = 1, 2, 1, 4
    x = jax.random.normal(ks[0], (B, S, H, P))
    dt = jax.nn.softplus(jax.random.normal(ks[1], (B, S, H))) * 0.1
    A_log = jax.random.normal(ks[2], (H,)) * 0.3
    Bm = jax.random.normal(ks[3], (B, S, G, N))
    Cm = jax.random.normal(ks[4], (B, S, G, N))
    D = jnp.ones((H,))
    y1, s1 = ssd_chunked(x, dt, A_log, Bm, Cm, D, chunk=8)
    y2, s2 = ssd_chunked(3.0 * x, dt, A_log, Bm, Cm, D, chunk=8)
    np.testing.assert_allclose(np.asarray(y2), 3.0 * np.asarray(y1),
                               rtol=2e-4, atol=2e-4)
    np.testing.assert_allclose(np.asarray(s2), 3.0 * np.asarray(s1),
                               rtol=2e-4, atol=2e-4)
