"""An internal error class excluded from the client taxonomy."""


class TransportError(RuntimeError):
    pass


# mpklint: disable=MPK202 reason=internal-only; never crosses the wire to a client
class BoomError(TransportError):
    pass
