"""A typed error the taxonomy table forgot."""


class TransportError(RuntimeError):
    pass


class BoomError(TransportError):
    pass
