"""Every typed error appears in the taxonomy."""


class TransportError(RuntimeError):
    pass


class BoomError(TransportError):
    pass
