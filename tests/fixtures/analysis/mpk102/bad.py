"""True positive: an arena-slot view escapes with no finalizer guard."""


class Poller:
    def poll(self, slot, verify_view):
        out = verify_view(slot.buf, seed=0)
        self.last = out
        return out
