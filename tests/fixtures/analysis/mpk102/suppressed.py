"""Suppressed: the slot is never recycled (static buffer)."""


class Poller:
    def poll(self, slot, verify_view):
        out = verify_view(slot.buf, seed=0)
        # mpklint: disable=MPK102 reason=slot.buf is session-static, never recycled
        return out
