"""True negative: release_on_collect pins the slot to the view's
lifetime; the lockstep region view has the until-next-exchange
contract and is exempt."""


class Poller:
    def poll(self, slot, verify_view):
        out = verify_view(slot.buf, seed=0)
        self.arena.release_on_collect(out, slot.buf)
        return out

    def lockstep(self, verify_view):
        return verify_view(self._region_resp[:4], seed=0)
