"""True positive: wall-clock deadline arithmetic."""
import time


def wait_until(timeout):
    deadline = time.time() + timeout
    while time.time() < deadline:
        pass
