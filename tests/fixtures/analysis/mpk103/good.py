"""True negative: monotonic deadlines; bare wall-clock timestamping of a
result record is legitimate."""
import time


def wait_until(timeout):
    deadline = time.monotonic() + timeout
    while time.monotonic() < deadline:
        pass


def stamp(record):
    record["ts"] = time.time()
    return record
