"""Suppressed: cross-host wall-clock delta is the point."""
import time


def clock_skew(peer_ts):
    # mpklint: disable=MPK103 reason=comparing wall clocks across hosts is the feature
    return time.time() - peer_ts
