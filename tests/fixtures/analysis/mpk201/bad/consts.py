"""Wire constants whose spec drifted."""
MAGIC = 0x4D504B4C
LANES = 128
