"""Wire constants in sync with the spec."""
MAGIC = 0x4D504B4C
LANES = 128
