"""A constant deliberately undocumented, with the reason inline."""
MAGIC = 0x4D504B4C
# mpklint: disable=MPK201 reason=internal debug magic, not part of the wire contract
GW_MAGIC = 0x44454247
