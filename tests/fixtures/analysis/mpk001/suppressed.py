"""Suppressed: the writer documents why the unguarded += is safe."""
import threading


class Worker:
    def __init__(self):
        self._lock = threading.Lock()
        self.count = 0
        self._thread = threading.Thread(target=self._run, daemon=True)

    def _run(self):
        # mpklint: disable=MPK001 reason=thread joined before bump() is callable
        self.count += 1

    def bump(self):
        # mpklint: disable=MPK001 reason=thread joined before bump() is callable
        self.count += 1
