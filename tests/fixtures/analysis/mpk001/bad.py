"""True positive: cross-thread counter bumped with no lock."""
import threading


class Worker:
    def __init__(self):
        self._lock = threading.Lock()
        self.count = 0
        self._thread = threading.Thread(target=self._run, daemon=True)

    def _run(self):
        self.count += 1

    def bump(self):
        self.count += 1
