"""True negative: same shape, but every += holds the owning lock; the
lock-free boolean flag write is the sanctioned doorbell idiom."""
import threading


class Worker:
    def __init__(self):
        self._lock = threading.Lock()
        self.count = 0
        self.ready = False
        self._thread = threading.Thread(target=self._run, daemon=True)

    def _run(self):
        with self._lock:
            self.count += 1
        self.ready = True

    def bump(self):
        with self._lock:
            self.count += 1
