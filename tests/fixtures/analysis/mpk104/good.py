"""True negative: the timeout reaches the blocking callee."""


class Client:
    def fetch(self, sock, timeout=1.0):
        sock.settimeout(timeout)
        return sock.recv(4096)
