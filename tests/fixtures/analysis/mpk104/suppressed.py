"""Suppressed: signature parity with an interface that cannot block."""


class Client:
    # mpklint: disable=MPK104 reason=interface parity; recv here is non-blocking
    def fetch(self, sock, timeout=1.0):
        return sock.recv(4096)
