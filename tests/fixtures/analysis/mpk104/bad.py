"""True positive: a timeout parameter accepted but never forwarded."""


class Client:
    def fetch(self, sock, timeout=1.0):
        return sock.recv(4096)
