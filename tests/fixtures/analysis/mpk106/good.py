"""True negative: every wait derives from the remaining budget."""
import time


class Dispatcher:
    def run(self, rep, deadline):
        remaining = deadline - time.monotonic()
        if not rep.rlock.acquire(timeout=min(remaining, 30.0)):
            raise TimeoutError
        return rep.session.request(b"x", timeout=remaining)
