"""Suppressed: a liveness probe's bound is deliberately fixed."""


class Prober:
    def probe(self, rep, probe_timeout):
        # mpklint: disable=MPK106 reason=health probe uses its own fixed bound by design
        if not rep.rlock.acquire(timeout=1.0):
            return "busy"
        return "alive"
