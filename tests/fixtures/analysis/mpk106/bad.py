"""True positive: a deadline-accepting dispatch waits on a fresh constant."""


class Dispatcher:
    def run(self, rep, deadline):
        if not rep.rlock.acquire(timeout=30.0):
            raise TimeoutError
        return rep.session.request(b"x", timeout=5.0 + 25.0)
