"""True positive: a disable comment with no reason — never suppresses."""
import time


def clock_skew(peer_ts):
    # mpklint: disable=MPK103
    return time.time() - peer_ts
