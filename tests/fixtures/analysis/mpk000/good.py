"""True negative: the disable carries its mandatory reason."""
import time


def clock_skew(peer_ts):
    # mpklint: disable=MPK103 reason=comparing wall clocks across hosts is the feature
    return time.time() - peer_ts
