"""True negative: sheds re-raised, mapped, or caught off the admission
path."""
import logging


def dispatch(gw, payload):
    try:
        return gw.call("svc", payload)
    except RateLimited as e:
        logging.warning("shed: retry in %.3fs", e.retry_after)
        raise


def submit(gw, payload):
    try:
        return gw.call("svc", payload)
    except Overloaded as e:
        return {"error": "overloaded", "retry_after": e.retry_after}


def teardown(conns):
    # not an admission-path name — best-effort cleanup is out of scope
    for c in conns:
        try:
            c.close()
        except TransportError:
            pass
