"""Suppressed: a probe call that is documented as fire-and-forget."""


def call_probe(gw):
    try:
        gw.call("health", b"")
    # mpklint: disable=MPK107 reason=liveness probe; shed means alive enough
    except Overloaded:
        pass
