"""True positive: admission paths eating typed shed signals."""


def dispatch(gw, payload):
    try:
        return gw.call("svc", payload)
    except RateLimited:
        return None                     # shed converted into a silent miss


def _admit_identity(gw, cid):
    try:
        gw.bucket.take(1)
    except (Overloaded, TransportError):
        pass                            # back-pressure never reaches caller
