"""Suppressed: the lock is the serializer by contract."""
import threading


class Server:
    def __init__(self):
        self._lock = threading.Lock()
        self._evt = threading.Event()

    def wait_done(self):
        with self._lock:
            # mpklint: disable=MPK002 reason=lock is the call serializer by contract
            self._evt.wait(1.0)
