"""True positive: blocking waits made while holding an unrelated lock."""
import threading
import time


class Server:
    def __init__(self):
        self._lock = threading.Lock()
        self._evt = threading.Event()

    def wait_done(self):
        with self._lock:
            self._evt.wait(1.0)

    def nap(self):
        with self._lock:
            time.sleep(0.1)
