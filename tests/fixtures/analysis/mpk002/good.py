"""True negative: parking on the held condition releases it (the
sanctioned idiom); the sleep happens outside the lock."""
import threading
import time


class Server:
    def __init__(self):
        self._cv = threading.Condition()
        self.done = False

    def wait_done(self):
        with self._cv:
            self._cv.wait_for(lambda: self.done, timeout=1.0)

    def nap(self):
        time.sleep(0.1)
        with self._cv:
            self.done = True
