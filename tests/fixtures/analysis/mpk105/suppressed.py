"""Suppressed: best-effort teardown documented as such."""


def close_all(conns):
    for c in conns:
        try:
            c.close()
        # mpklint: disable=MPK105 reason=best-effort teardown; session already dead
        except Exception:
            pass
