"""True positive: a broad handler eating every typed error."""


def close_all(conns):
    for c in conns:
        try:
            c.close()
        except Exception:
            pass
