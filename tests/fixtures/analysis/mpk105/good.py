"""True negative: the handler is narrow (and the broad one acts)."""
import logging


def close_all(conns):
    for c in conns:
        try:
            c.close()
        except OSError:
            pass
        except Exception as e:
            logging.warning("close failed: %s", e)
