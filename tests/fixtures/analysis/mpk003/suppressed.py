"""Suppressed: the reversed order is unreachable concurrently."""
import threading


class Pair:
    def __init__(self):
        self.lock_a = threading.Lock()
        self.lock_b = threading.Lock()

    def forward(self):
        with self.lock_a:
            # mpklint: disable=MPK003 reason=backward() only runs single-threaded at shutdown
            with self.lock_b:
                pass

    def backward(self):
        with self.lock_b:
            with self.lock_a:
                pass
