"""True negative: both paths agree on one global acquisition order."""
import threading


class Pair:
    def __init__(self):
        self.lock_a = threading.Lock()
        self.lock_b = threading.Lock()

    def forward(self):
        with self.lock_a:
            with self.lock_b:
                pass

    def also_forward(self):
        with self.lock_a:
            with self.lock_b:
                pass
