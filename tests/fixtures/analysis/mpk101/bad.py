"""True positive: payload rows read before any verify dominates them."""


def handle(sock):
    frame = sock.recv_frame()
    payload = frame[1:]
    return payload.sum()
