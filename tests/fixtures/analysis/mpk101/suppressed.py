"""Suppressed: a diagnostics path that never trusts the bytes."""


def peek(sock):
    frame = sock.recv_frame()
    # mpklint: disable=MPK101 reason=hexdump diagnostics; bytes never acted on
    raw = frame[1:]
    return raw.tobytes().hex()
