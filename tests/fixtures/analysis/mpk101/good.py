"""True negative: verify_view dominates the payload access."""


def handle(sock, verify_view):
    frame = sock.recv_frame()
    payload = verify_view(frame, seed=0)
    tail = frame[1:]
    return payload, tail
