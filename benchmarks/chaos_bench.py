"""Chaos benchmark: gateway throughput/latency under injected fault rates.

For every transport × fault rate ∈ {0%, 1%, 5%, 10%} the bench drives one
strict client (retries=0 — every fault must surface as its typed error)
through a seeded FaultPlan over N requests of the paper's §VI wordcount
workload, and records throughput, p50/p99 latency, per-outcome counts and
the *sustained fraction* (faulted throughput / fault-free throughput).
A healing-mode cell (retries=2 + idempotency tokens) is run for
mpklink_opt at 10% to show the self-healing path: liveness faults recover,
nothing double-executes.

Acceptance gates (exit code 1 on violation — CI uses this):
  * every non-faulted request completes with the correct answer;
  * every faulted request resolves (typed error or recovery) within 2× the
    transport timeout — nothing hangs;
  * mpklink_opt at 10% sustains > 50% of its fault-free throughput.

  PYTHONPATH=src python benchmarks/chaos_bench.py [--quick] [--out f.json]

Replay any cell locally from the JSON: each cell records its FaultPlan
spec; ``FaultPlan.from_spec(cell["plan"])`` rebuilds the exact schedule.
"""
from __future__ import annotations

import argparse
import json
import os
import time
from typing import Dict, List

import numpy as np

from repro.core import ServiceGateway
from repro.core.faultwire import FaultFabric, FaultPlan, FaultyClient
from repro.core.wordcount import make_text, parse_count, wordcount_handler

TRANSPORTS_ORDER = ["pipe", "uds", "shm", "grpc_sim", "mpklink", "mpklink_opt"]
RATES = [0.0, 0.01, 0.05, 0.10]
WORDS = 2_000                       # §VI workload payload (≈14 KB)
TIMEOUT = 0.08                      # transport response deadline (s)
DELAY = 0.005                       # injected delay_response stall (s)
SEED = 20_240_722


def run_cell(transport: str, rate: float, n_requests: int, *,
             retries: int = 0, seed: int = SEED) -> Dict:
    gw = ServiceGateway(transport, transport_kwargs={"timeout": TIMEOUT})
    gw.register_service("wordcount", wordcount_handler,
                        factory=lambda: wordcount_handler)
    gw.start()
    client = gw.connect(f"chaos-{transport}-{rate}", retries=retries)
    payloads = [make_text(WORDS, seed=j) for j in range(16)]
    expected = [parse_count(wordcount_handler(p)) for p in payloads]
    for j in range(8):                  # warmup off the clock, pre-fabric
        client.call("wordcount", payloads[j])
    plan = FaultPlan(seed=seed, n_requests=n_requests, rate=rate, delay=DELAY)
    fab = FaultFabric(plan).attach(gw)
    fc = FaultyClient(client, fab, "wordcount")

    lat: List[float] = []
    fault_lat: List[float] = []
    wrong = 0
    t0 = time.perf_counter()
    try:
        for i in range(n_requests):
            t1 = time.perf_counter()
            out = fc.step(payloads[i % 16])
            dt = time.perf_counter() - t1
            (fault_lat if out.kind is not None else lat).append(dt)
            if out.status == "ok" and parse_count(out.value) != expected[i % 16]:
                wrong += 1
    finally:
        wall = time.perf_counter() - t0
        gw.close()

    counts = fc.counts()
    lat_a = np.sort(np.asarray(lat)) if lat else np.zeros(1)
    cell = {
        "transport": transport,
        "rate": rate,
        "requests": n_requests,
        "retries": retries,
        "injected": len(plan.events),
        "plan": plan.spec(),
        "seconds": round(wall, 4),
        "throughput_rps": round(n_requests / wall, 2),
        "p50_ms": round(float(np.percentile(lat_a, 50)) * 1e3, 3),
        "p99_ms": round(float(np.percentile(lat_a, 99)) * 1e3, 3),
        "max_fault_ms": round(max(fault_lat) * 1e3, 3) if fault_lat else 0.0,
        "counts": counts,
        "wrong_answers": wrong,
        "stats": dict(gw.stats),
        # gates (per cell): no collateral errors, no wrong answers, every
        # fault resolved within 2× the transport deadline
        "non_faulted_ok": counts["error"] == 0 and wrong == 0,
        "faults_bounded": (not fault_lat
                          or max(fault_lat) < 2 * TIMEOUT + DELAY),
    }
    return cell


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true",
                    help="mpklink variants only, fewer requests")
    ap.add_argument("--out", default=None, help="write JSON here too")
    args = ap.parse_args()

    transports = (["mpklink", "mpklink_opt"] if args.quick
                  else TRANSPORTS_ORDER)
    n = 120 if args.quick else 500

    results = []
    for name in transports:
        base_rps = None
        for rate in RATES:
            cell = run_cell(name, rate, n)
            if rate == 0.0:
                base_rps = cell["throughput_rps"]
            cell["sustained_frac"] = (
                round(cell["throughput_rps"] / base_rps, 3)
                if base_rps else None)
            results.append(cell)
            print(f"  {name:<12} rate={rate:>4.0%} "
                  f"{cell['throughput_rps']:>8} req/s "
                  f"p50={cell['p50_ms']}ms p99={cell['p99_ms']}ms "
                  f"sustained={cell['sustained_frac']} "
                  f"{cell['counts']}", flush=True)

    # healing mode: bounded retry + idempotency tokens on the flagship cell
    heal = run_cell("mpklink_opt", 0.10, n, retries=2)
    heal["sustained_frac"] = None
    results.append(heal)
    print(f"  mpklink_opt  rate=10% HEALING {heal['throughput_rps']:>8} req/s "
          f"{heal['counts']} deduped={heal['stats']['deduped']}", flush=True)

    flagship = next(r for r in results
                    if r["transport"] == "mpklink_opt" and r["rate"] == 0.10
                    and r["retries"] == 0)
    gates = {
        "all_non_faulted_ok": all(r["non_faulted_ok"] for r in results),
        "all_faults_bounded": all(r["faults_bounded"] for r in results),
        "mpklink_opt_10pct_sustained_frac": flagship["sustained_frac"],
        # throughput gate only at full scale: n=120 quick cells are too
        # noisy for a ratio of two wall-clock measurements to be meaningful
        "mpklink_opt_10pct_sustains_half": (
            flagship["sustained_frac"] > 0.5 if not args.quick else None),
        "healing_all_recovered": heal["counts"]["error"] == 0
                                 and heal["non_faulted_ok"],
    }
    report = {
        "meta": {"transports": transports, "rates": RATES, "requests": n,
                 "words": WORDS, "timeout_s": TIMEOUT, "delay_s": DELAY,
                 "seed": SEED},
        "results": results,
        "gates": gates,
    }
    blob = json.dumps(report, indent=2)
    print(blob)
    if args.out:
        os.makedirs(os.path.dirname(args.out) or ".", exist_ok=True)
        with open(args.out, "w") as f:
            f.write(blob)
    ok = (gates["all_non_faulted_ok"] and gates["all_faults_bounded"]
          and gates["mpklink_opt_10pct_sustains_half"] is not False
          and gates["healing_all_recovered"])
    if not ok:
        print("CHAOS BENCH GATES FAILED", flush=True)
    return 0 if ok else 1


if __name__ == "__main__":
    raise SystemExit(main())
