"""Roofline report: aggregates artifacts/dryrun/*.json into the per-cell
three-term table (EXPERIMENTS.md §Roofline).

Run the dry-run first:  PYTHONPATH=src python -m repro.launch.dryrun --both-meshes
"""
from __future__ import annotations

import glob
import json
import os
from typing import List

ARTIFACTS = os.path.join(os.path.dirname(__file__), "..", "artifacts", "dryrun")


def load(mesh: str = None) -> List[dict]:
    rows = []
    for path in sorted(glob.glob(os.path.join(ARTIFACTS, "*.json"))):
        with open(path) as f:
            r = json.load(f)
        if r.get("status") != "ok":
            continue
        if mesh and r["mesh"] != mesh:
            continue
        rows.append(r)
    return rows


def fmt_row(r: dict) -> str:
    rf = r["roofline"]
    t = (rf["t_compute_s"], rf["t_memory_s"], rf["t_collective_s"])
    tb = max(t)
    frac = tb / sum(t) if sum(t) else 0
    useful = r.get("useful_flops_ratio") or 0
    peak = (r["memory"].get("peak_bytes") or 0) / 2 ** 30
    tag = r.get("opts", "base")
    return (f"{r['arch']:<24}{r['shape']:<13}{r['mesh']:<9}{tag:<30}"
            f"{t[0]:>10.3f} {t[1]:>10.3f} {t[2]:>10.3f}  "
            f"{rf['bottleneck']:<11}{frac:>5.2f} {useful:>7.3f} {peak:>7.2f}")


def main():
    rows = load()
    if not rows:
        print("no artifacts — run the dry-run first")
        return
    hdr = (f"{'arch':<24}{'shape':<13}{'mesh':<9}{'opts':<30}"
           f"{'t_comp(s)':>10} {'t_mem(s)':>10} {'t_coll(s)':>10}  "
           f"{'bound':<11}{'frac':>5} {'useful':>7} {'GiB/dev':>7}")
    print(hdr)
    print("-" * len(hdr))
    for r in sorted(rows, key=lambda r: (r["mesh"], r["arch"], r["shape"])):
        print(fmt_row(r))
    print()
    print("bench,case,us_per_call,derived")
    for r in rows:
        rf = r["roofline"]
        tb = max(rf["t_compute_s"], rf["t_memory_s"], rf["t_collective_s"])
        tag = r.get("opts", "base")
        print(f"roofline,{r['arch']}__{r['shape']}__{r['mesh']}__{tag},"
              f"{tb*1e6:.1f},{rf['bottleneck']}")


if __name__ == "__main__":
    main()
