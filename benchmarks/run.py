# One function per paper table. Print ``name,us_per_call,derived`` CSV.
"""Benchmark harness entry point.

  table1/fig1-3  — benchmarks/ipc_wordcount.py: the paper's word-count IPC
                   comparison across the six transports + claim validation
  baseline fight — benchmarks/ipc_baseline_bench.py: process-backed
                   mpklink_opt vs real loopback REST / socket-RPC servers
                   (§VI), with the 2x-over-REST acceptance gate
  fleet          — benchmarks/fleet_bench.py: 1 vs 4 proc-backed replicas
                   behind one service name under open-loop Poisson/bursty
                   load + kill -9 chaos, with the 2x-scaling and
                   zero-lost acceptance gates
  qos            — benchmarks/qos_bench.py: noisy-neighbor cell (one
                   abuser flooding at 20x fair share vs 15 victims) with
                   the victim-p99 and abuser-throttle acceptance gates
  tableX         — benchmarks/kernel_bench.py: guarded copy vs plain copy
                   (the "security rides the copy" comparative analysis §VIII-A)
                   + attention / SSD kernel twins
  roofline       — benchmarks/roofline_report.py: per-cell roofline terms
                   from the dry-run artifacts (if present)

``python -m benchmarks.run [--full] [--only <bench>]``

``--only`` runs a single sub-bench by name (``ipc_wordcount``,
``ipc_baseline``, ``fleet``, ``qos``, ``kernel``, ``roofline``) — the CI
jobs use it to gate one bench without paying for the whole suite.

Exits nonzero when any sub-bench fails — a crashed bench or a FAILed
paper claim must fail the invoking job, not scroll past in the log.
"""
import argparse
import sys


def _run_ipc_wordcount(full: bool, failures):
    from benchmarks import ipc_wordcount
    try:
        results = ipc_wordcount.main(full=full)
        # claim lines print PASS / FAIL / DEVIATION; only FAIL (a
        # measured contradiction, not an env deviation) is fatal
        failed = [line for line
                  in ipc_wordcount.validate_claims(results)
                  if ": FAIL" in line]
        if failed:
            failures.append(f"ipc_wordcount: {len(failed)} claim(s) FAILed")
    except Exception as e:
        failures.append(f"ipc_wordcount crashed: {type(e).__name__}: {e}")


def _module_bench(name):
    """Runner for the gate benches whose ``main(argv)`` takes
    ``--quick``/full argv (ipc_baseline_bench, fleet_bench, qos_bench)."""
    def run(full: bool, failures):
        import importlib
        mod = importlib.import_module(f"benchmarks.{name}")
        try:
            rc = mod.main([] if full else ["--quick"])
            if rc not in (None, 0):
                failures.append(f"{name} exited {rc}")
        except Exception as e:
            failures.append(f"{name} crashed: {type(e).__name__}: {e}")
    return run


def _run_kernel(full: bool, failures):
    from benchmarks import kernel_bench
    try:
        rc = kernel_bench.main()
        if rc not in (None, 0):
            failures.append(f"kernel_bench exited {rc}")
    except Exception as e:
        failures.append(f"kernel_bench crashed: {type(e).__name__}: {e}")


def _run_roofline(full: bool, failures):
    from benchmarks import roofline_report
    try:
        rc = roofline_report.main()
        if rc not in (None, 0):
            failures.append(f"roofline_report exited {rc}")
    except Exception as e:
        failures.append(f"roofline_report crashed: {type(e).__name__}: {e}")


# (name, banner, runner, skipped by --skip-ipc)
BENCHES = [
    ("ipc_wordcount", "ipc_wordcount (paper Figs 1-3, Table I)",
     _run_ipc_wordcount, True),
    ("ipc_baseline",
     "ipc_baseline_bench (paper §VI: process-backed vs REST)",
     _module_bench("ipc_baseline_bench"), True),
    ("fleet", "fleet_bench (replicated serving fleet, 1 vs 4 replicas)",
     _module_bench("fleet_bench"), True),
    ("qos", "qos_bench (multi-tenant noisy neighbor, §10 QoS gates)",
     _module_bench("qos_bench"), True),
    ("kernel", "kernel_bench (paper §VIII-A comparative analysis)",
     _run_kernel, False),
    ("roofline", "roofline (dry-run artifacts)",
     _run_roofline, False),
]


def main() -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--full", action="store_true",
                    help="word-count sweep to 1e8 words (paper endpoint)")
    ap.add_argument("--skip-ipc", action="store_true")
    ap.add_argument("--only", default=None,
                    choices=[name for name, _, _, _ in BENCHES],
                    help="run a single sub-bench by name")
    args = ap.parse_args()

    failures = []
    for name, banner, runner, ipc_gated in BENCHES:
        if args.only is not None and name != args.only:
            continue
        print(f"# === {banner} ===")
        if args.only is None and ipc_gated and args.skip_ipc:
            print()
            continue
        runner(args.full, failures)
        print()

    if failures:
        print()
        print("# BENCH SUITE FAILED:")
        for f in failures:
            print(f"#   - {f}")
        return 1
    return 0


if __name__ == "__main__":
    sys.exit(main())
