# One function per paper table. Print ``name,us_per_call,derived`` CSV.
"""Benchmark harness entry point.

  table1/fig1-3  — benchmarks/ipc_wordcount.py: the paper's word-count IPC
                   comparison across the six transports + claim validation
  baseline fight — benchmarks/ipc_baseline_bench.py: process-backed
                   mpklink_opt vs real loopback REST / socket-RPC servers
                   (§VI), with the 2x-over-REST acceptance gate
  fleet          — benchmarks/fleet_bench.py: 1 vs 4 proc-backed replicas
                   behind one service name under open-loop Poisson/bursty
                   load + kill -9 chaos, with the 2x-scaling and
                   zero-lost acceptance gates
  tableX         — benchmarks/kernel_bench.py: guarded copy vs plain copy
                   (the "security rides the copy" comparative analysis §VIII-A)
                   + attention / SSD kernel twins
  roofline       — benchmarks/roofline_report.py: per-cell roofline terms
                   from the dry-run artifacts (if present)

``python -m benchmarks.run [--full]``

Exits nonzero when any sub-bench fails — a crashed bench or a FAILed
paper claim must fail the invoking job, not scroll past in the log.
"""
import argparse
import sys


def main() -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--full", action="store_true",
                    help="word-count sweep to 1e8 words (paper endpoint)")
    ap.add_argument("--skip-ipc", action="store_true")
    args = ap.parse_args()

    failures = []

    print("# === ipc_wordcount (paper Figs 1-3, Table I) ===")
    if not args.skip_ipc:
        from benchmarks import ipc_wordcount
        try:
            results = ipc_wordcount.main(full=args.full)
            # claim lines print PASS / FAIL / DEVIATION; only FAIL (a
            # measured contradiction, not an env deviation) is fatal
            failed = [line for line
                      in ipc_wordcount.validate_claims(results)
                      if ": FAIL" in line]
            if failed:
                failures.append(f"ipc_wordcount: {len(failed)} claim(s) "
                                f"FAILed")
        except Exception as e:
            failures.append(f"ipc_wordcount crashed: "
                            f"{type(e).__name__}: {e}")
    print()
    print("# === ipc_baseline_bench (paper §VI: process-backed vs REST) ===")
    if not args.skip_ipc:
        from benchmarks import ipc_baseline_bench
        try:
            rc = ipc_baseline_bench.main(
                [] if args.full else ["--quick"])
            if rc not in (None, 0):
                failures.append(f"ipc_baseline_bench exited {rc}")
        except Exception as e:
            failures.append(f"ipc_baseline_bench crashed: "
                            f"{type(e).__name__}: {e}")
    print()
    print("# === fleet_bench (replicated serving fleet, 1 vs 4 replicas) ===")
    if not args.skip_ipc:
        from benchmarks import fleet_bench
        try:
            rc = fleet_bench.main([] if args.full else ["--quick"])
            if rc not in (None, 0):
                failures.append(f"fleet_bench exited {rc}")
        except Exception as e:
            failures.append(f"fleet_bench crashed: "
                            f"{type(e).__name__}: {e}")
    print()
    print("# === kernel_bench (paper §VIII-A comparative analysis) ===")
    from benchmarks import kernel_bench
    try:
        rc = kernel_bench.main()
        if rc not in (None, 0):
            failures.append(f"kernel_bench exited {rc}")
    except Exception as e:
        failures.append(f"kernel_bench crashed: {type(e).__name__}: {e}")
    print()
    print("# === roofline (dry-run artifacts) ===")
    from benchmarks import roofline_report
    try:
        rc = roofline_report.main()
        if rc not in (None, 0):
            failures.append(f"roofline_report exited {rc}")
    except Exception as e:
        failures.append(f"roofline_report crashed: {type(e).__name__}: {e}")

    if failures:
        print()
        print("# BENCH SUITE FAILED:")
        for f in failures:
            print(f"#   - {f}")
        return 1
    return 0


if __name__ == "__main__":
    sys.exit(main())
