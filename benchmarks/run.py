# One function per paper table. Print ``name,us_per_call,derived`` CSV.
"""Benchmark harness entry point.

  table1/fig1-3  — benchmarks/ipc_wordcount.py: the paper's word-count IPC
                   comparison across the six transports + claim validation
  tableX         — benchmarks/kernel_bench.py: guarded copy vs plain copy
                   (the "security rides the copy" comparative analysis §VIII-A)
                   + attention / SSD kernel twins
  roofline       — benchmarks/roofline_report.py: per-cell roofline terms
                   from the dry-run artifacts (if present)

``python -m benchmarks.run [--full]``
"""
import argparse


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--full", action="store_true",
                    help="word-count sweep to 1e8 words (paper endpoint)")
    ap.add_argument("--skip-ipc", action="store_true")
    args = ap.parse_args()

    print("# === ipc_wordcount (paper Figs 1-3, Table I) ===")
    if not args.skip_ipc:
        from benchmarks import ipc_wordcount
        ipc_wordcount.main(full=args.full)
    print()
    print("# === kernel_bench (paper §VIII-A comparative analysis) ===")
    from benchmarks import kernel_bench
    kernel_bench.main()
    print()
    print("# === roofline (dry-run artifacts) ===")
    from benchmarks import roofline_report
    roofline_report.main()


if __name__ == "__main__":
    main()
