"""Kernel microbenchmarks (CPU timings of the jnp twins + interpret-mode
sanity; the structural claim measured here is the paper's Table-X
"security rides the copy": guard_copy (tag+MAC+copy) vs a plain copy at
matched sizes — the delta is the *security overhead of the data plane*."""
from __future__ import annotations

import time
from typing import Callable

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.transports import fast_mac
from repro.kernels.flash_jnp import flash_attention_jnp
from repro.kernels.ref import attention_ref, mac_ref, ssd_ref
from repro.kernels.ssd_jnp import ssd_chunked


def timeit(fn: Callable, reps=5, warmup=2) -> float:
    for _ in range(warmup):
        fn()
    ts = []
    for _ in range(reps):
        t0 = time.perf_counter()
        fn()
        ts.append(time.perf_counter() - t0)
    return sorted(ts)[len(ts) // 2]


def bench_guard_vs_copy():
    """Host data plane: authenticated copy vs memcpy (numpy, both O(n))."""
    rows = []
    for n_rows in (256, 4096, 65536):           # 128 KiB .. 32 MiB
        payload = np.random.default_rng(0).integers(
            0, 2 ** 32, (n_rows, 128), dtype=np.uint64).astype(np.uint32)
        dst = np.empty_like(payload)

        def plain():
            np.copyto(dst, payload)

        def guarded():
            np.copyto(dst, payload)
            fast_mac(payload, 0xAB)

        t_plain = timeit(plain)
        t_guard = timeit(guarded)
        rows.append(("guard_vs_copy", f"{n_rows*512//1024}KiB",
                     t_guard * 1e6, t_guard / max(t_plain, 1e-9)))
    return rows


def bench_attention():
    rows = []
    B, H, Hkv, Dh = 1, 8, 2, 64
    for S in (256, 1024):
        ks = jax.random.split(jax.random.PRNGKey(0), 3)
        q = jax.random.normal(ks[0], (B, S, H, Dh))
        k = jax.random.normal(ks[1], (B, S, Hkv, Dh))
        v = jax.random.normal(ks[2], (B, S, Hkv, Dh))
        pos = jnp.broadcast_to(jnp.arange(S, dtype=jnp.int32), (B, S))
        naive = jax.jit(lambda q, k, v: attention_ref(q, k, v, pos, pos))
        flash = jax.jit(lambda q, k, v: flash_attention_jnp(q, k, v, pos, pos))
        naive(q, k, v).block_until_ready()
        flash(q, k, v).block_until_ready()
        tn = timeit(lambda: naive(q, k, v).block_until_ready())
        tf = timeit(lambda: flash(q, k, v).block_until_ready())
        rows.append(("attn_naive", f"S{S}", tn * 1e6, S))
        rows.append(("attn_flash_jnp", f"S{S}", tf * 1e6, tf / tn))
    return rows


def bench_ssd():
    rows = []
    B, H, P, G, N = 1, 8, 32, 1, 32
    for S in (512, 2048):
        ks = jax.random.split(jax.random.PRNGKey(0), 5)
        x = jax.random.normal(ks[0], (B, S, H, P))
        dt = jax.nn.softplus(jax.random.normal(ks[1], (B, S, H))) * 0.1
        A_log = jax.random.normal(ks[2], (H,)) * 0.5
        Bm = jax.random.normal(ks[3], (B, S, G, N))
        Cm = jax.random.normal(ks[4], (B, S, G, N))
        D = jnp.ones((H,))
        seq = jax.jit(lambda *a: ssd_ref(*a)[0])
        chk = jax.jit(lambda *a: ssd_chunked(*a, chunk=128)[0])
        seq(x, dt, A_log, Bm, Cm, D).block_until_ready()
        chk(x, dt, A_log, Bm, Cm, D).block_until_ready()
        ts = timeit(lambda: seq(x, dt, A_log, Bm, Cm, D).block_until_ready())
        tc = timeit(lambda: chk(x, dt, A_log, Bm, Cm, D).block_until_ready())
        rows.append(("ssd_sequential", f"S{S}", ts * 1e6, S))
        rows.append(("ssd_chunked", f"S{S}", tc * 1e6, ts / tc))
    return rows


def main():
    print("bench,case,us_per_call,derived")
    for fn in (bench_guard_vs_copy, bench_attention, bench_ssd):
        for name, case, us, derived in fn():
            print(f"{name},{case},{us:.1f},{derived:.3f}")


if __name__ == "__main__":
    main()
