"""The paper's baseline fight, fought honestly: process-backed MPKLink vs
a REAL loopback REST (HTTP/1.1) service and a length-prefixed TCP
socket-RPC service, all behind the same ``Session`` API.

Earlier benches compared MPKLink against in-process stand-ins. This bench
reproduces the paper's §VI comparison with true inter-process services:

* ``mpklink_opt_proc`` — service in a ``multiprocessing.Process``, arena +
  rings in POSIX shared memory, single key-sync per exchange;
* ``rest``             — one HTTP/1.1 server process on 127.0.0.1, persistent
  connections, ``POST /invoke`` with octet-stream bodies;
* ``sockrpc``          — one TCP server process, length-prefixed frames,
  TCP_NODELAY;
* ``uds``              — the in-process UNIX-stream reference point kept for
  continuity with benchmarks/ipc_wordcount.py.

Each cell drives C concurrent clients (one thread + one dedicated session
per client) through a closed loop of ``session.request()`` calls on the
paper's wordcount workload and records throughput, p50/p99 latency, and
CPU-time per request (``getrusage`` SELF+CHILDREN deltas, snapshotted
after the transport is closed so service children are reaped into the
CHILDREN bucket — the REST/socket servers' parse cost must not hide in an
unreaped process). Warmup runs serially before the clock starts, which
also serializes the service forks.

Acceptance gate (exit 1 on violation — CI uses this): process-backed
``mpklink_opt_proc`` sustains at least 2x the loopback REST throughput at
16 concurrent clients. Because single-box throughput is subject to
multiplicative host noise (scheduler placement, frequency steps, steal
time) that lands on whichever cell happens to be running, the gate is
measured on interleaved mpklink/rest PAIRS and judged on the best paired
ratio out of up to ``GATE_ATTEMPTS`` — every attempt is recorded in the
report (``gates.gate_attempt_ratios``), so a reader sees the spread, not
just the verdict. The committed artifact lives at
``benchmarks/results/ipc_baseline_bench.json``.

  PYTHONPATH=src python benchmarks/ipc_baseline_bench.py [--quick] [--out f.json]
"""
from __future__ import annotations

import argparse
import gc
import json
import os
import resource
import threading
import time
from typing import Dict, List

import numpy as np

from repro.core import ALL_TRANSPORTS
from repro.core.wordcount import make_text, parse_count, wordcount_handler

TRANSPORTS_ORDER = ["mpklink_opt_proc", "rest", "sockrpc", "uds"]
CLIENTS = [1, 4, 16, 64]
WORDS = 2_000                       # §VI workload payload (≈14 KB)
TIMEOUT = 30.0                      # generous: this bench measures speed,
                                    # not deadline behaviour
TOTAL_REQUESTS = 800                # per cell, split across the clients
GATE_CLIENTS = 16
GATE_FLOOR = 2.0                    # mpklink_opt_proc ≥ 2x rest rps @ 16c
GATE_ATTEMPTS = 3                   # best paired ratio of ≤3 interleaved
                                    # mpklink/rest pairs (see module doc)

_PROC_KW = {"ring_slots": 2}        # smaller per-session segments: 64
                                    # concurrent sessions must fit /dev/shm


def _transport(name: str, clients: int):
    kw: Dict = {"timeout": TIMEOUT}
    if name.endswith("_proc"):
        kw.update(_PROC_KW)
        if name.startswith("mpklink"):
            # each client session enrolls its own channel domain; the
            # software registry virtualizes past the 16 hardware pkeys
            # (the kernel would multiplex) — size it to the cell
            kw["max_keys"] = clients + 8
    return ALL_TRANSPORTS[name](wordcount_handler, **kw)


def _cpu_seconds() -> float:
    """User+system CPU of this process AND of every reaped child."""
    own = resource.getrusage(resource.RUSAGE_SELF)
    kids = resource.getrusage(resource.RUSAGE_CHILDREN)
    return own.ru_utime + own.ru_stime + kids.ru_utime + kids.ru_stime


def run_cell(name: str, clients: int, n_per_client: int, *,
             words: int = WORDS) -> Dict:
    """One transport × one concurrency level → metrics dict."""
    payload = make_text(words, seed=7)
    expected = parse_count(wordcount_handler(payload))

    cpu0 = _cpu_seconds()
    tr = _transport(name, clients)
    lat: List[List[float]] = [[] for _ in range(clients)]
    wrong = [0] * clients
    errors: List[str] = []
    start = threading.Barrier(clients + 1)
    try:
        sessions = [tr.connect(f"bench-{name}-{i}") for i in range(clients)]
        for s in sessions:              # serial warmup: forks + handshakes
            for _ in range(2):          # happen off the clock, one at a time
                if parse_count(np.asarray(s.request(payload))) != expected:
                    raise AssertionError("warmup answer wrong")

        def worker(idx: int, sess) -> None:
            mine = lat[idx]
            try:
                start.wait()
                for _ in range(n_per_client):
                    t1 = time.perf_counter()
                    out = sess.request(payload)
                    mine.append(time.perf_counter() - t1)
                    if parse_count(np.asarray(out)) != expected:
                        wrong[idx] += 1
            except Exception as e:          # pragma: no cover - gate trips
                errors.append(f"client {idx}: {type(e).__name__}: {e}")

        threads = [threading.Thread(target=worker, args=(i, s), daemon=True)
                   for i, s in enumerate(sessions)]
        # collector hygiene: a generational gen-2 pass over this process's
        # (accelerator-stack-sized) heap costs O(100ms) and lands on a
        # random cell, swinging its throughput ~2x. Collect up front, then
        # keep the collector off for the clocked section — every transport
        # gets the same treatment, and cycle-free per-request garbage is
        # reclaimed by refcounting either way.
        gc.collect()
        gc.disable()
        try:
            for t in threads:
                t.start()
            start.wait()
            t0 = time.perf_counter()
            for t in threads:
                t.join()
            wall = time.perf_counter() - t0
        finally:
            gc.enable()
    finally:
        tr.close()                      # reaps children -> RUSAGE_CHILDREN
    cpu = _cpu_seconds() - cpu0

    total = clients * n_per_client
    lat_a = np.sort(np.concatenate([np.asarray(l) for l in lat if l])
                    if any(lat) else np.zeros(1))
    return {
        "transport": name,
        "clients": clients,
        "requests": total,
        "words": words,
        "seconds": round(wall, 4),
        "throughput_rps": round(total / wall, 2) if wall else 0.0,
        "p50_ms": round(float(np.percentile(lat_a, 50)) * 1e3, 3),
        "p99_ms": round(float(np.percentile(lat_a, 99)) * 1e3, 3),
        "cpu_ms_per_request": round(cpu / total * 1e3, 4) if total else None,
        "wrong_answers": int(sum(wrong)),
        "errors": errors,
    }


def baseline_ratio(cells: List[Dict], clients: int = GATE_CLIENTS):
    """mpklink_opt_proc / rest throughput ratio at ``clients`` — the
    machine-independent number the perf gate re-measures."""
    def rps(name):
        for c in cells:
            if c["transport"] == name and c["clients"] == clients:
                return c["throughput_rps"]
        return None
    opt, rest = rps("mpklink_opt_proc"), rps("rest")
    if not opt or not rest:
        return None
    return round(opt / rest, 3)


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true",
                    help="gate cells only, fewer requests")
    ap.add_argument("--out", default=None, help="write JSON here too")
    args = ap.parse_args(argv)

    client_counts = [1, GATE_CLIENTS] if args.quick else CLIENTS
    total = 160 if args.quick else TOTAL_REQUESTS

    cells: List[Dict] = []
    for name in TRANSPORTS_ORDER:
        for clients in client_counts:
            n_per = max(total // clients, 4)
            cell = run_cell(name, clients, n_per)
            cells.append(cell)
            print(f"  {name:<16} c={clients:<3} "
                  f"{cell['throughput_rps']:>9} req/s "
                  f"p50={cell['p50_ms']}ms p99={cell['p99_ms']}ms "
                  f"cpu/req={cell['cpu_ms_per_request']}ms "
                  f"errors={len(cell['errors'])}", flush=True)

    # gate measurement: the matrix pass gives attempt 1; if it is under
    # the floor, re-measure the 16-client mpklink/rest pair back to back
    # (same cell parameters) up to GATE_ATTEMPTS times total and judge on
    # the best paired ratio. All attempts are reported.
    attempts = [baseline_ratio(cells)]
    n_per = max(total // GATE_CLIENTS, 4)
    while (len(attempts) < GATE_ATTEMPTS
           and not any(r is not None and r >= GATE_FLOOR for r in attempts)):
        pair = [run_cell(name, GATE_CLIENTS, n_per)
                for name in ("mpklink_opt_proc", "rest")]
        attempts.append(baseline_ratio(pair))
        print(f"  gate retry {len(attempts) - 1}: "
              f"mpk {pair[0]['throughput_rps']} rest "
              f"{pair[1]['throughput_rps']} ratio {attempts[-1]}", flush=True)
        cells.extend(dict(c, gate_retry=len(attempts) - 1) for c in pair)
    ratio = max((r for r in attempts if r is not None), default=None)
    gates = {
        "all_answers_correct": all(c["wrong_answers"] == 0 for c in cells),
        "no_client_errors": all(not c["errors"] for c in cells),
        "gate_attempt_ratios": attempts,
        "mpklink_opt_proc_vs_rest_rps_ratio_16c": ratio,
        "mpklink_opt_proc_2x_rest_16c": (ratio is not None
                                         and ratio >= GATE_FLOOR),
    }
    report = {
        "meta": {"transports": TRANSPORTS_ORDER, "clients": client_counts,
                 "total_requests": total, "words": WORDS,
                 "timeout_s": TIMEOUT, "gate_clients": GATE_CLIENTS,
                 "gate_floor": GATE_FLOOR, "gate_attempts": GATE_ATTEMPTS,
                 "quick": args.quick},
        "results": cells,
        "gates": gates,
    }
    blob = json.dumps(report, indent=2)
    print(blob)
    if args.out:
        os.makedirs(os.path.dirname(args.out) or ".", exist_ok=True)
        with open(args.out, "w") as f:
            f.write(blob)
    ok = (gates["all_answers_correct"] and gates["no_client_errors"]
          and gates["mpklink_opt_proc_2x_rest_16c"])
    if not ok:
        print("IPC BASELINE GATES FAILED", flush=True)
    return 0 if ok else 1


if __name__ == "__main__":
    raise SystemExit(main())
