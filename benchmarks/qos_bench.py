"""Noisy-neighbor QoS gate: one abusive tenant flooding at ABUSE_X times
its fair share vs VICTIMS well-behaved tenants, behind one gateway with
the full §10 stack armed — a per-identity token bucket on the abuser and
weighted fair queuing over the fleet's in-flight slots.

The replica handler models a DEVICE-BOUND step (sleep ``SERVICE_MS``
then echo), the same honesty argument as fleet_bench: wall-clock service
time is real, host CPU is not, so fair-queue slots are the contended
resource the way replica slots are in production. Victim load is open
loop (seeded Poisson at ``FAIR_RATE`` per tenant, latency measured from
the SCHEDULED arrival, slip included). The abuser is open loop at
``ABUSE_X * FAIR_RATE`` with catch-up semantics — when the schedule is
behind it floods back-to-back, ignoring every ``retry_after`` hint, the
worst cooperative-protocol violator the admission layer must absorb.

Cells:
  * ``solo``      — one victim alone at FAIR_RATE → the baseline p99;
  * ``qos``       — VICTIMS victims + the abuser, bucket armed at
                    FAIR_RATE (burst ABUSE_BURST). GATED.
  * ``unlimited`` — same load, NO bucket (WFQ only). Recorded, not
                    gated: it documents that the fair queue alone keeps
                    victims alive while the *bucket* is what throttles
                    the abuser's admitted throughput.

Acceptance gates (exit 1 on violation; CI re-asserts the committed
booleans via perf_gate.py):
  * ``victim_p99_le_2x_solo``: victim p99 in the qos cell stays within
    ``VICTIM_P99_MULT`` (2x) of the solo baseline p99 — best paired
    attempt out of up to GATE_ATTEMPTS, single-box noise is
    multiplicative;
  * ``abuser_throughput_le_1p2x_rate``: the abuser's ADMITTED
    throughput is at most ``ABUSER_TPUT_MULT`` (1.2x) its configured
    rate — the bucket holds under flood;
  * every admitted answer is bit-correct and every shed is the typed
    ``RateLimited`` (anything untyped is a loss and fails the gate).

  PYTHONPATH=src python benchmarks/qos_bench.py [--quick] [--out f.json]
"""
from __future__ import annotations

import argparse
import gc
import json
import os
import threading
import time
from typing import Dict, List, Optional

import numpy as np

from repro.core.gateway import ServiceGateway
from repro.core.transports import (Overloaded, RateLimited, ResponseTimeout,
                                   ServiceUnavailable)

SERVICE_MS = 5.0                    # device-bound handler model (sleep)
VICTIMS = 15                        # well-behaved tenants
FAIR_RATE = 15.0                    # per-tenant fair share, req/s
ABUSE_X = 20.0                      # abuser offered load: 20x fair share
ABUSE_BURST = 5.0                   # abuser bucket burst (tokens)
N_PER_VICTIM = 200                  # per-victim requests (~13 s span)
# capacity: each in-proc replica serves its session serially, so the
# fleet's ceiling is REPLICAS / SERVICE_MS = 800 req/s against ~240 req/s
# offered — the victims run BELOW saturation and the abuser's 20x flood
# is what would collapse them without admission control
REPLICAS = 4                        # in-proc replica fleet
GATE_CAPACITY = 8                   # fair-queue in-flight slots
TIMEOUT = 30.0
PAYLOAD_BYTES = 64

VICTIM_P99_MULT = 2.0               # qos victim p99 <= 2x solo p99
ABUSER_TPUT_MULT = 1.2              # admitted rps <= 1.2x configured rate
GATE_ATTEMPTS = 3                   # best paired solo/qos attempt

_REPLICA_KW = {"ring_slots": 2, "timeout": TIMEOUT}


def _decode_handler(req):
    time.sleep(SERVICE_MS / 1e3)
    return np.asarray(req, np.uint8)


def poisson_schedule(rate_rps: float, n: int, seed: int) -> np.ndarray:
    rng = np.random.default_rng(seed)
    return np.cumsum(rng.exponential(1.0 / rate_rps, size=n))


def _qos_gateway(clients: int) -> ServiceGateway:
    gw = ServiceGateway("mpklink_opt", max_keys=2 * clients + 64,
                        transport_kwargs={"timeout": TIMEOUT})
    for _ in range(REPLICAS):
        gw.register_replica("decode", _decode_handler,
                            transport="mpklink_opt",
                            transport_kwargs=dict(_REPLICA_KW))
    gw.start()
    gw.fleet("decode").enable_fair_queue(GATE_CAPACITY)
    return gw


def run_cell(victims: int, n_per_victim: int, *, abuser: bool = False,
             limit: bool = True, seed: int = 0x0A05) -> Dict:
    """One load mix → metrics dict. ``victims`` open-loop tenants at
    FAIR_RATE each; with ``abuser`` a 20x-fair-share flooder joins, its
    bucket armed at FAIR_RATE when ``limit``."""
    payload = np.frombuffer(os.urandom(PAYLOAD_BYTES), np.uint8)
    gw = _qos_gateway(victims + 2)
    if abuser and limit:
        gw.set_rate_limit("abuser", rate=FAIR_RATE, burst=ABUSE_BURST)
    span_est = n_per_victim / FAIR_RATE
    n_abuse = int(ABUSE_X * FAIR_RATE * span_est)
    lock = threading.Lock()
    victim_lat: List[float] = []
    abuse_lat: List[float] = []
    sheds = [0]
    typed: List[str] = []
    lost: List[str] = []
    wrong = [0]
    last_done = [0.0]
    parties = victims + (1 if abuser else 0) + 1
    barrier = threading.Barrier(parties)

    def victim(idx: int, t0: float):
        cli = gw.connect(f"victim-{idx}")
        schedule = poisson_schedule(FAIR_RATE, n_per_victim, seed + idx)
        try:
            barrier.wait()
            for k in range(n_per_victim):
                target = t0 + schedule[k]
                delay = target - time.perf_counter()
                if delay > 0:
                    time.sleep(delay)
                try:
                    out = cli.call("decode", payload)
                    done = time.perf_counter()
                    with lock:
                        victim_lat.append(done - target)
                        last_done[0] = max(last_done[0], done)
                        if bytes(np.asarray(out)) != bytes(payload):
                            wrong[0] += 1
                except (ServiceUnavailable, ResponseTimeout) as e:
                    with lock:
                        typed.append(type(e).__name__)
                except Exception as e:  # pragma: no cover - gate trips
                    with lock:
                        lost.append(f"victim {type(e).__name__}: {e}")
        finally:
            cli.close()

    def abuse(t0: float):
        """The flood: open loop at ABUSE_X * FAIR_RATE with catch-up —
        behind schedule it hammers back-to-back and never honors
        retry_after."""
        cli = gw.connect("abuser")
        schedule = poisson_schedule(ABUSE_X * FAIR_RATE, n_abuse, seed + 999)
        end_at = t0 + span_est
        try:
            barrier.wait()
            for k in range(n_abuse):
                now = time.perf_counter()
                if now >= end_at:
                    break               # victims are done; stop the flood
                delay = t0 + schedule[k] - now
                if delay > 0:
                    time.sleep(delay)
                try:
                    out = cli.call("decode", payload)
                    done = time.perf_counter()
                    with lock:
                        abuse_lat.append(done - (t0 + schedule[k]))
                        last_done[0] = max(last_done[0], done)
                        if bytes(np.asarray(out)) != bytes(payload):
                            wrong[0] += 1
                except RateLimited:
                    with lock:
                        sheds[0] += 1
                except (Overloaded, ServiceUnavailable, ResponseTimeout) as e:
                    with lock:
                        typed.append(type(e).__name__)
                except Exception as e:  # pragma: no cover - gate trips
                    with lock:
                        lost.append(f"abuser {type(e).__name__}: {e}")
        finally:
            cli.close()

    try:
        warm = gw.connect("warm")
        for _ in range(3 * REPLICAS):
            warm.call("decode", payload)
        warm.close()
        gc.collect()
        gc.disable()
        try:
            t0 = time.perf_counter() + 0.05
            threads = [threading.Thread(target=victim, args=(i, t0),
                                        daemon=True) for i in range(victims)]
            if abuser:
                threads.append(threading.Thread(target=abuse, args=(t0,),
                                                daemon=True))
            for t in threads:
                t.start()
            barrier.wait()
            for t in threads:
                t.join()
        finally:
            gc.enable()
        qos = gw.qos_stats()
        fleet_stats = dict(gw.fleet("decode").stats)
        gw_rate_limited = gw.stats["rate_limited"]
    finally:
        gw.close()

    span = max(1e-9, last_done[0] - t0)
    vl = np.sort(np.asarray(victim_lat) if victim_lat else np.zeros(1))
    return {
        "victims": victims,
        "abuser": abuser,
        "rate_limited_tenant": bool(abuser and limit),
        "fair_rate_rps": FAIR_RATE,
        "abuse_offered_rps": ABUSE_X * FAIR_RATE if abuser else 0.0,
        "service_ms": SERVICE_MS,
        "gate_capacity": GATE_CAPACITY,
        "seconds": round(span, 4),
        "victim_completed": len(victim_lat),
        "victim_p50_ms": round(float(np.percentile(vl, 50)) * 1e3, 3),
        "victim_p99_ms": round(float(np.percentile(vl, 99)) * 1e3, 3),
        "abuser_admitted": len(abuse_lat),
        "abuser_admitted_rps": round(len(abuse_lat) / span, 2),
        "abuser_rate_limited": sheds[0],
        "gw_rate_limited_total": gw_rate_limited,
        "typed_errors": sorted(set(typed)),
        "typed_error_count": len(typed),
        "lost": lost,
        "wrong_answers": wrong[0],
        "qos_stats": qos,
        "fleet_stats": fleet_stats,
    }


def victim_ratio(solo: Dict, noisy: Dict) -> Optional[float]:
    """noisy-cell victim p99 over the solo baseline p99 — the
    machine-independent number the perf gate re-measures."""
    base = solo["victim_p99_ms"]
    if not base:
        return None
    return round(noisy["victim_p99_ms"] / base, 3)


def abuser_ratio(noisy: Dict) -> float:
    """abuser admitted throughput over its configured rate."""
    return round(noisy["abuser_admitted_rps"] / FAIR_RATE, 3)


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true",
                    help="shorter schedules (CI re-measure)")
    ap.add_argument("--out", default=None, help="write JSON here too")
    args = ap.parse_args(argv)

    n = 60 if args.quick else N_PER_VICTIM

    def show(c, label):
        print(f"  {label:<10} victims={c['victims']:<3} "
              f"victim p50={c['victim_p50_ms']}ms "
              f"p99={c['victim_p99_ms']}ms "
              f"abuser {c['abuser_admitted_rps']:>7} req/s admitted "
              f"({c['abuser_rate_limited']} rate-limited) "
              f"typed={c['typed_error_count']} lost={len(c['lost'])} "
              f"wrong={c['wrong_answers']}", flush=True)

    # best paired (solo, qos) attempt: single-box noise is multiplicative
    # on whichever cell is running, so the pair is judged together
    solo = qos = None
    v_ratio = a_ratio = None
    for attempt in range(GATE_ATTEMPTS):
        s = run_cell(1, n)
        q = run_cell(VICTIMS, n, abuser=True, limit=True)
        show(s, "solo")
        show(q, "qos")
        vr, ar = victim_ratio(s, q), abuser_ratio(q)
        print(f"  attempt {attempt}: victim p99 ratio={vr} "
              f"abuser throughput ratio={ar}", flush=True)
        better = (v_ratio is None
                  or (vr is not None and vr < v_ratio))
        if better:
            solo, qos, v_ratio, a_ratio = s, q, vr, ar
        if (v_ratio is not None and v_ratio <= VICTIM_P99_MULT
                and a_ratio <= ABUSER_TPUT_MULT
                and not q["lost"] and not s["lost"]):
            break

    # WFQ-only context cell: no bucket — the fair queue keeps victims
    # alive while the abuser takes whatever it asks for (recorded, the
    # contrast that shows the bucket is what throttles)
    unlimited = run_cell(VICTIMS, n, abuser=True, limit=False)
    show(unlimited, "unlimited")

    gates = {
        "victim_solo_p99_ms": solo["victim_p99_ms"],
        "victim_qos_p99_ms": qos["victim_p99_ms"],
        "victim_p99_ratio_vs_solo": v_ratio,
        "victim_p99_le_2x_solo": (v_ratio is not None
                                  and v_ratio <= VICTIM_P99_MULT),
        "abuser_admitted_rps": qos["abuser_admitted_rps"],
        "abuser_throughput_ratio_vs_rate": a_ratio,
        "abuser_throughput_le_1p2x_rate": (a_ratio is not None
                                           and a_ratio <= ABUSER_TPUT_MULT),
        "abuser_sheds_typed": qos["abuser_rate_limited"] > 0,
        "all_answers_correct": all(c["wrong_answers"] == 0
                                   for c in (solo, qos, unlimited)),
        "no_lost_requests": all(not c["lost"]
                                for c in (solo, qos, unlimited)),
        "unlimited_abuser_admitted_rps": unlimited["abuser_admitted_rps"],
    }
    report = {
        "meta": {"victims": VICTIMS, "n_per_victim": n,
                 "fair_rate_rps": FAIR_RATE, "abuse_x": ABUSE_X,
                 "abuse_burst": ABUSE_BURST, "service_ms": SERVICE_MS,
                 "replicas": REPLICAS, "gate_capacity": GATE_CAPACITY,
                 "victim_p99_mult": VICTIM_P99_MULT,
                 "abuser_tput_mult": ABUSER_TPUT_MULT,
                 "gate_attempts": GATE_ATTEMPTS, "quick": args.quick},
        "results": {"solo": solo, "qos": qos, "unlimited": unlimited},
        "gates": gates,
    }
    blob = json.dumps(report, indent=2)
    print(blob)
    if args.out:
        os.makedirs(os.path.dirname(args.out) or ".", exist_ok=True)
        with open(args.out, "w") as f:
            f.write(blob)
    ok = (gates["victim_p99_le_2x_solo"]
          and gates["abuser_throughput_le_1p2x_rate"]
          and gates["abuser_sheds_typed"]
          and gates["all_answers_correct"]
          and gates["no_lost_requests"])
    if not ok:
        print("QOS GATES FAILED", flush=True)
    return 0 if ok else 1


if __name__ == "__main__":
    raise SystemExit(main())
