"""Gateway concurrency benchmark: N clients × named services × transports.

Sweeps clients ∈ {1, 4, 16, 64} against two named services multiplexed over
one transport:

  wordcount   the paper's §VI workload (cheap handler — measures the
              gateway + transport path under concurrency)
  infer       token generation through runtime/serve.py's ServingEngine —
              continuous batching absorbs the concurrent load, so aggregate
              throughput should scale strongly with client count until the
              slot grid saturates

A second sweep measures the **pipelined data plane**: one client keeping
k ∈ {1, 4, 16} messages in flight per round trip via ``call_batch`` (batch
envelope + vectorized MAC + native engine batch submission) against the
lockstep single-in-flight baseline — the JSON's ``batch_results`` /
``batch_speedup_16_over_lockstep`` section, with the acceptance gate that
batched mpklink_opt at 16 in flight sustains ≥ 2× lockstep throughput while
every frame is still MAC-verified on both sides.

Emits JSON: per-cell throughput (req/s), p50/p99 latency (ms), key-sync
counts (mpklink variants), server/client MAC-verification counts, and a
scaling summary (16-client vs 1-client throughput per transport/service).
Methodology notes live in docs/benchmarks.md.

  PYTHONPATH=src python benchmarks/gateway_bench.py [--quick] [--no-batch]
      [--out f.json]
"""
from __future__ import annotations

import argparse
import json
import threading
import time
from typing import Dict, List, Optional

import numpy as np

from repro.core import ServiceGateway
from repro.core.transports import MPKLinkTransport
from repro.core.wordcount import make_text, wordcount_handler

CLIENTS = [1, 4, 16, 64]
TRANSPORTS_ORDER = ["pipe", "uds", "shm", "grpc_sim", "mpklink", "mpklink_opt"]
WORDS = 2_000                         # wordcount payload (≈14 KB)
PROMPT_LEN = 4
MAX_NEW = 16                          # decode-dominated requests: the regime
                                      # where continuous batching pays


def build_engine_service(max_batch: int = 32, max_seq: int = 64):
    """Tiny-model ServingEngine behind the thread-safe EngineService."""
    import jax
    from repro.configs import get_reduced
    from repro.models import init_params
    from repro.models.transformer import Impl
    from repro.runtime import EngineService, ServingEngine, encode_prompt

    cfg = get_reduced("llama3.2-1b")
    params = init_params(cfg, jax.random.PRNGKey(0))
    engine = ServingEngine(cfg, params, max_batch=max_batch, max_seq=max_seq,
                           impl=Impl(attention="naive", remat=False))
    svc = EngineService(engine).start()
    svc.handler(encode_prompt([1, 2, 3], max_new=2))   # jit warmup off the clock
    return svc


def run_cell(gw: ServiceGateway, service: str, n_clients: int, reps: int,
             make_payload) -> Dict:
    """n_clients threads, each with its own gateway client/session, all
    hammering ``service`` for ``reps`` requests; wall-clocked together."""
    latencies: List[List[float]] = [[] for _ in range(n_clients)]
    errors: List[str] = []
    clients = [gw.connect(f"bench-{service}-{n_clients}-{i}")
               for i in range(n_clients)]
    for c in clients:                       # channel setup off the clock
        c.open(service)
    barrier = threading.Barrier(n_clients + 1)

    def worker(i):
        c = clients[i]
        try:
            barrier.wait()
            for j in range(reps):
                t0 = time.perf_counter()
                c.call(service, make_payload(i, j))
                latencies[i].append(time.perf_counter() - t0)
        except Exception as e:              # pragma: no cover
            errors.append(repr(e))

    threads = [threading.Thread(target=worker, args=(i,))
               for i in range(n_clients)]
    for t in threads:
        t.start()
    stats0 = dict(gw.stats)
    sync0 = getattr(gw.transport, "sync_count", 0)
    barrier.wait()
    t0 = time.perf_counter()
    for t in threads:
        t.join()
    wall = time.perf_counter() - t0
    stats1 = dict(gw.stats)
    sync1 = getattr(gw.transport, "sync_count", 0)
    client_macs = sum(c.macs_verified for c in clients)
    for c in clients:
        c.close()

    lats = np.asarray(sorted(sum(latencies, [])))
    total = int(lats.size)
    server_macs = stats1["macs_verified"] - stats0["macs_verified"]
    return {
        "service": service,
        "clients": n_clients,
        "requests": total,
        "errors": errors,
        "seconds": round(wall, 4),
        "throughput_rps": round(total / wall, 2) if wall > 0 else None,
        "p50_ms": round(float(np.percentile(lats, 50)) * 1e3, 3) if total else None,
        "p99_ms": round(float(np.percentile(lats, 99)) * 1e3, 3) if total else None,
        "key_syncs": sync1 - sync0,
        "macs_verified_server": server_macs,
        "macs_verified_clients": client_macs,
        "all_macs_verified": (not errors and server_macs == total
                              and client_macs == total),
        "rejected": stats1["rejected"] - stats0["rejected"],
    }


def sweep(transports: List[str], clients: List[int], reps_wordcount: int,
          reps_infer: int, engine_service) -> List[Dict]:
    results = []
    for name in transports:
        gw = ServiceGateway(name, max_keys=256)
        gw.register_service("wordcount", wordcount_handler)
        if engine_service is not None:
            gw.register_service("infer", engine_service.handler)
        gw.start()
        try:
            for n in clients:
                cell = run_cell(
                    gw, "wordcount", n, reps_wordcount,
                    lambda i, j: make_text(WORDS, seed=i * 131 + j))
                cell["transport"] = name
                results.append(cell)
                print(f"  {name:<12} wordcount c={n:<3} "
                      f"{cell['throughput_rps']:>9} req/s "
                      f"p50={cell['p50_ms']}ms p99={cell['p99_ms']}ms "
                      f"syncs={cell['key_syncs']}", flush=True)
                if engine_service is not None:
                    from repro.runtime import encode_prompt
                    cell = run_cell(
                        gw, "infer", n, reps_infer,
                        lambda i, j: encode_prompt(
                            [1 + (i + j) % 29, 2, 3, 4][:PROMPT_LEN],
                            max_new=MAX_NEW))
                    cell["transport"] = name
                    results.append(cell)
                    print(f"  {name:<12} infer     c={n:<3} "
                          f"{cell['throughput_rps']:>9} req/s "
                          f"p50={cell['p50_ms']}ms p99={cell['p99_ms']}ms",
                          flush=True)
        finally:
            gw.close()
    return results


BATCH_IN_FLIGHT = [1, 4, 16]


def run_batch_cell(gw: ServiceGateway, service: str, in_flight: int,
                   total_msgs: int, make_payload, mode: str) -> Dict:
    """One client pushing ``total_msgs`` messages at ``in_flight`` per round
    trip. ``mode='lockstep'`` issues them one call() at a time (the
    single-in-flight baseline); ``mode='batched'`` sends them as
    ``call_batch`` envelopes of ``in_flight`` messages."""
    client = gw.connect(f"bench-batch-{service}-{mode}-{in_flight}")
    client.open(service)                        # channel setup off the clock
    stats0 = dict(gw.stats)
    sync0 = getattr(gw.transport, "sync_count", 0)
    lat: List[float] = []
    errors: List[str] = []
    sent = 0
    t0 = time.perf_counter()
    while sent < total_msgs:
        k = min(in_flight, total_msgs - sent)
        payloads = [make_payload(sent + j) for j in range(k)]
        tb = time.perf_counter()
        try:
            if mode == "lockstep":
                for p in payloads:
                    client.call(service, p)
            else:
                client.call_batch(service, payloads)
        except Exception as e:                  # pragma: no cover
            errors.append(repr(e))
            break
        lat.append(time.perf_counter() - tb)
        sent += k
    wall = time.perf_counter() - t0
    stats1 = dict(gw.stats)
    sync1 = getattr(gw.transport, "sync_count", 0)
    server_macs = stats1["macs_verified"] - stats0["macs_verified"]
    client_macs = client.macs_verified
    client.close()
    lats = np.asarray(sorted(lat))
    return {
        "service": service,
        "mode": mode,
        "in_flight": in_flight,
        "messages": sent,
        "errors": errors,
        "seconds": round(wall, 4),
        "throughput_rps": round(sent / wall, 2) if wall > 0 else None,
        "p50_batch_ms": round(float(np.percentile(lats, 50)) * 1e3, 3)
        if lat else None,
        "p99_batch_ms": round(float(np.percentile(lats, 99)) * 1e3, 3)
        if lat else None,
        "key_syncs": sync1 - sync0,
        "macs_verified_server": server_macs,
        "macs_verified_clients": client_macs,
        "all_macs_verified": (not errors and server_macs == sent
                              and client_macs == sent),
        "rejected": stats1["rejected"] - stats0["rejected"],
    }


def sweep_batch(transports: List[str], total_msgs: int, infer_msgs: int,
                engine_service) -> List[Dict]:
    """Lockstep baseline + batched cells per transport (and the engine
    service's native batch path when available)."""
    results = []
    for name in transports:
        gw = ServiceGateway(name, max_keys=256)
        gw.register_service("wordcount", wordcount_handler)
        if engine_service is not None:
            gw.register_service("infer", engine_service.handler,
                                batch_handler=engine_service.handler_batch)
        gw.start()
        try:
            cells = [("lockstep", 1)] + [("batched", k)
                                         for k in BATCH_IN_FLIGHT]
            for mode, k in cells:
                cell = run_batch_cell(
                    gw, "wordcount", k, total_msgs,
                    lambda j: make_text(WORDS, seed=j), mode)
                cell["transport"] = name
                results.append(cell)
                print(f"  {name:<12} wordcount {mode:<8} k={k:<3} "
                      f"{cell['throughput_rps']:>9} msg/s "
                      f"syncs={cell['key_syncs']}", flush=True)
                if engine_service is not None:
                    from repro.runtime import encode_prompt
                    cell = run_batch_cell(
                        gw, "infer", k, infer_msgs,
                        lambda j: encode_prompt(
                            [1 + j % 29, 2, 3, 4][:PROMPT_LEN],
                            max_new=MAX_NEW), mode)
                    cell["transport"] = name
                    results.append(cell)
                    print(f"  {name:<12} infer     {mode:<8} k={k:<3} "
                          f"{cell['throughput_rps']:>9} msg/s", flush=True)
        finally:
            gw.close()
    return results


def batch_speedup(batch_results: List[Dict]) -> Dict[str, Optional[float]]:
    """Batched 16-in-flight vs lockstep 1-in-flight throughput per
    (transport, service) — the pipelining payoff."""
    out = {}
    by = {(r["transport"], r["service"], r["mode"], r["in_flight"]): r
          for r in batch_results}
    for (tr, svc, mode, k), r in sorted(by.items()):
        if mode != "batched" or k != 16:
            continue
        base = by.get((tr, svc, "lockstep", 1))
        if base and base["throughput_rps"]:
            out[f"{tr}/{svc}"] = round(
                r["throughput_rps"] / base["throughput_rps"], 2)
    return out


def scaling_summary(results: List[Dict]) -> Dict[str, Optional[float]]:
    """16-client vs 1-client aggregate throughput per (transport, service)."""
    out = {}
    by = {(r["transport"], r["service"], r["clients"]): r for r in results}
    for (tr, svc, n), r in sorted(by.items()):
        if n != 16:
            continue
        base = by.get((tr, svc, 1))
        if base and base["throughput_rps"]:
            out[f"{tr}/{svc}"] = round(
                r["throughput_rps"] / base["throughput_rps"], 2)
    return out


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true",
                    help="mpklink variants only, clients ≤ 16, fewer reps")
    ap.add_argument("--no-infer", action="store_true",
                    help="skip the ServingEngine-backed service")
    ap.add_argument("--no-batch", action="store_true",
                    help="skip the pipelined batch sweep")
    ap.add_argument("--out", default=None, help="write JSON here too")
    args = ap.parse_args()

    transports = (["mpklink", "mpklink_opt"] if args.quick
                  else TRANSPORTS_ORDER)
    clients = [c for c in CLIENTS if c <= (16 if args.quick else 64)]
    reps_wc = 4 if args.quick else 8
    reps_inf = 2 if args.quick else 6
    batch_msgs = 32 if args.quick else 64
    infer_msgs = 8 if args.quick else 16
    batch_transports = (["mpklink_opt"] if args.quick
                        else ["mpklink", "mpklink_opt"])

    engine_service = None if args.no_infer else build_engine_service()
    try:
        results = sweep(transports, clients, reps_wc, reps_inf, engine_service)
        batch_results = ([] if args.no_batch else
                         sweep_batch(batch_transports, batch_msgs,
                                     infer_msgs, engine_service))
    finally:
        if engine_service is not None:
            engine_service.close()

    speedup = batch_speedup(batch_results)
    report = {
        "meta": {"clients": clients, "transports": transports,
                 "wordcount_words": WORDS, "prompt_len": PROMPT_LEN,
                 "max_new": MAX_NEW, "batch_in_flight": BATCH_IN_FLIGHT,
                 "batch_msgs": batch_msgs},
        "results": results,
        "scaling_16c_over_1c": scaling_summary(results),
        "batch_results": batch_results,
        "batch_speedup_16_over_lockstep": speedup,
        "batch_gate_mpklink_opt_2x": (
            None if not batch_results
            else speedup.get("mpklink_opt/wordcount", 0) >= 2.0),
        "all_macs_verified": all(r["all_macs_verified"]
                                 for r in results + batch_results),
    }
    blob = json.dumps(report, indent=2)
    print(blob)
    if args.out:
        with open(args.out, "w") as f:
            f.write(blob)
    return report


if __name__ == "__main__":
    main()
