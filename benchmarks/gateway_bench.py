"""Gateway concurrency benchmark: N clients × named services × transports.

Sweeps clients ∈ {1, 4, 16, 64} against two named services multiplexed over
one transport:

  wordcount   the paper's §VI workload (cheap handler — measures the
              gateway + transport path under concurrency)
  infer       token generation through runtime/serve.py's ServingEngine —
              continuous batching absorbs the concurrent load, so aggregate
              throughput should scale strongly with client count until the
              slot grid saturates

A second sweep measures the **pipelined data plane**: one client keeping
k ∈ {1, 4, 16} messages in flight per round trip via ``call_batch`` (batch
envelope + vectorized MAC + native engine batch submission) against the
lockstep single-in-flight baseline — the JSON's ``batch_results`` /
``batch_speedup_16_over_lockstep`` section, with the acceptance gate that
batched mpklink_opt at 16 in flight sustains ≥ 2× lockstep throughput while
every frame is still MAC-verified on both sides.

A third sweep measures the **zero-copy seal path** (``payload_results``):
one client pushing ≥64 KiB payloads lockstep with the in-place arena seal
(``framing.ZERO_COPY=True``) vs the PR 3 copy pattern
(``framing.ZERO_COPY=False`` — pad/header concat + frame-to-region copy),
per transport. The framing stats hook records bytes-copied-per-request and
concat calls, proving the hot path concat-free; gate: mpklink_opt
zero-copy ≥ 1.5× legacy on every ≥64 KiB size.

A fourth sweep measures the **sharded parallel executor**
(``scatter_results``): one client fanning one request to each of 4
services (handlers model I/O-bound microservices: a small sleep + a
vectorized digest) as sequential ``call()`` round trips (the PR 3 path) vs
one ``call_many`` scatter envelope with ``workers ∈ {0, 4}``; gate:
workers=4 scatter ≥ 2× the sequential baseline aggregate throughput.

The HEADLINE sweep is the **high-fan-in coalescing sweep**
(``fanin_results``): 64–256 concurrent clients issuing inline ``call()``s
on small payloads (the per-message-overhead-dominated regime of
containerized microservice RPC), with the gateway's auto-batching mux
(``enable_coalescing``) off (every client pays its own round trip:
key syncs + doorbell wakeups + scalar MAC) vs on (concurrent calls fold
into scatter cohorts: one round trip / one fused MAC pass / one wakeup
per cohort — callers unchanged). The framing stats hook reports
wakeups-per-request and key-syncs-per-request. Gates:
``coalesce_gate_mpklink_opt_64c_2x`` (coalesced ≥ 2× inline rps at 64
clients) and ``coalesce_wakeup_gate_4x`` (wakeups/request reduced ≥ 4×),
with every frame still MAC-verified on both sides.

Emits JSON: per-cell throughput (req/s), p50/p99 latency (ms), key-sync
counts (mpklink variants), server/client MAC-verification counts,
bytes-copied-per-request, wakeups/request, and a scaling summary.
Methodology notes live in docs/benchmarks.md.

  PYTHONPATH=src python benchmarks/gateway_bench.py [--quick] [--no-batch]
      [--no-payload] [--no-scatter] [--no-fanin] [--out f.json]
"""
from __future__ import annotations

import argparse
import json
import threading
import time
from typing import Dict, List, Optional

import numpy as np

from repro.core import ServiceGateway, framing
from repro.core.transports import MPKLinkTransport
from repro.core.wordcount import make_text, wordcount_handler

CLIENTS = [1, 4, 16, 64]
TRANSPORTS_ORDER = ["pipe", "uds", "shm", "grpc_sim", "mpklink", "mpklink_opt"]
WORDS = 2_000                         # wordcount payload (≈14 KB)
PROMPT_LEN = 4
MAX_NEW = 16                          # decode-dominated requests: the regime
                                      # where continuous batching pays
PAYLOAD_SIZES = [64 * 1024, 256 * 1024, 1024 * 1024]   # zero-copy sweep
PAYLOAD_IN_FLIGHT = 4                 # pipelined operating point (gated)
SCATTER_SERVICES = 4
SCATTER_DELAY = 0.003                 # simulated downstream I/O per handler


def build_engine_service(max_batch: int = 32, max_seq: int = 64):
    """Tiny-model ServingEngine behind the thread-safe EngineService."""
    import jax
    from repro.configs import get_reduced
    from repro.models import init_params
    from repro.models.transformer import Impl
    from repro.runtime import EngineService, ServingEngine, encode_prompt

    cfg = get_reduced("llama3.2-1b")
    params = init_params(cfg, jax.random.PRNGKey(0))
    engine = ServingEngine(cfg, params, max_batch=max_batch, max_seq=max_seq,
                           impl=Impl(attention="naive", remat=False))
    svc = EngineService(engine).start()
    svc.handler(encode_prompt([1, 2, 3], max_new=2))   # jit warmup off the clock
    return svc


def run_cell(gw: ServiceGateway, service: str, n_clients: int, reps: int,
             make_payload) -> Dict:
    """n_clients threads, each with its own gateway client/session, all
    hammering ``service`` for ``reps`` requests; wall-clocked together."""
    latencies: List[List[float]] = [[] for _ in range(n_clients)]
    errors: List[str] = []
    clients = [gw.connect(f"bench-{service}-{n_clients}-{i}")
               for i in range(n_clients)]
    for c in clients:                       # channel setup off the clock
        c.open(service)
    barrier = threading.Barrier(n_clients + 1)

    def worker(i):
        c = clients[i]
        try:
            barrier.wait()
            for j in range(reps):
                t0 = time.perf_counter()
                c.call(service, make_payload(i, j))
                latencies[i].append(time.perf_counter() - t0)
        except Exception as e:              # pragma: no cover
            errors.append(repr(e))

    threads = [threading.Thread(target=worker, args=(i,))
               for i in range(n_clients)]
    for t in threads:
        t.start()
    stats0 = dict(gw.stats)
    sync0 = getattr(gw.transport, "sync_count", 0)
    barrier.wait()
    t0 = time.perf_counter()
    for t in threads:
        t.join()
    wall = time.perf_counter() - t0
    stats1 = dict(gw.stats)
    sync1 = getattr(gw.transport, "sync_count", 0)
    client_macs = sum(c.macs_verified for c in clients)
    for c in clients:
        c.close()

    lats = np.asarray(sorted(sum(latencies, [])))
    total = int(lats.size)
    server_macs = stats1["macs_verified"] - stats0["macs_verified"]
    return {
        "service": service,
        "clients": n_clients,
        "requests": total,
        "errors": errors,
        "seconds": round(wall, 4),
        "throughput_rps": round(total / wall, 2) if wall > 0 else None,
        "p50_ms": round(float(np.percentile(lats, 50)) * 1e3, 3) if total else None,
        "p99_ms": round(float(np.percentile(lats, 99)) * 1e3, 3) if total else None,
        "key_syncs": sync1 - sync0,
        "macs_verified_server": server_macs,
        "macs_verified_clients": client_macs,
        "all_macs_verified": (not errors and server_macs == total
                              and client_macs == total),
        "rejected": stats1["rejected"] - stats0["rejected"],
    }


def sweep(transports: List[str], clients: List[int], reps_wordcount: int,
          reps_infer: int, engine_service) -> List[Dict]:
    results = []
    for name in transports:
        gw = ServiceGateway(name, max_keys=256)
        gw.register_service("wordcount", wordcount_handler)
        if engine_service is not None:
            gw.register_service("infer", engine_service.handler)
        gw.start()
        try:
            for n in clients:
                cell = run_cell(
                    gw, "wordcount", n, reps_wordcount,
                    lambda i, j: make_text(WORDS, seed=i * 131 + j))
                cell["transport"] = name
                results.append(cell)
                print(f"  {name:<12} wordcount c={n:<3} "
                      f"{cell['throughput_rps']:>9} req/s "
                      f"p50={cell['p50_ms']}ms p99={cell['p99_ms']}ms "
                      f"syncs={cell['key_syncs']}", flush=True)
                if engine_service is not None:
                    from repro.runtime import encode_prompt
                    cell = run_cell(
                        gw, "infer", n, reps_infer,
                        lambda i, j: encode_prompt(
                            [1 + (i + j) % 29, 2, 3, 4][:PROMPT_LEN],
                            max_new=MAX_NEW))
                    cell["transport"] = name
                    results.append(cell)
                    print(f"  {name:<12} infer     c={n:<3} "
                          f"{cell['throughput_rps']:>9} req/s "
                          f"p50={cell['p50_ms']}ms p99={cell['p99_ms']}ms",
                          flush=True)
        finally:
            gw.close()
    return results


BATCH_IN_FLIGHT = [1, 4, 16]


def run_batch_cell(gw: ServiceGateway, service: str, in_flight: int,
                   total_msgs: int, make_payload, mode: str) -> Dict:
    """One client pushing ``total_msgs`` messages at ``in_flight`` per round
    trip. ``mode='lockstep'`` issues them one call() at a time (the
    single-in-flight baseline); ``mode='batched'`` sends them as
    ``call_batch`` envelopes of ``in_flight`` messages."""
    client = gw.connect(f"bench-batch-{service}-{mode}-{in_flight}")
    client.open(service)                        # channel setup off the clock
    stats0 = dict(gw.stats)
    sync0 = getattr(gw.transport, "sync_count", 0)
    lat: List[float] = []
    errors: List[str] = []
    sent = 0
    t0 = time.perf_counter()
    while sent < total_msgs:
        k = min(in_flight, total_msgs - sent)
        payloads = [make_payload(sent + j) for j in range(k)]
        tb = time.perf_counter()
        try:
            if mode == "lockstep":
                for p in payloads:
                    client.call(service, p)
            else:
                client.call_batch(service, payloads)
        except Exception as e:                  # pragma: no cover
            errors.append(repr(e))
            break
        lat.append(time.perf_counter() - tb)
        sent += k
    wall = time.perf_counter() - t0
    stats1 = dict(gw.stats)
    sync1 = getattr(gw.transport, "sync_count", 0)
    server_macs = stats1["macs_verified"] - stats0["macs_verified"]
    client_macs = client.macs_verified
    client.close()
    lats = np.asarray(sorted(lat))
    return {
        "service": service,
        "mode": mode,
        "in_flight": in_flight,
        "messages": sent,
        "errors": errors,
        "seconds": round(wall, 4),
        "throughput_rps": round(sent / wall, 2) if wall > 0 else None,
        "p50_batch_ms": round(float(np.percentile(lats, 50)) * 1e3, 3)
        if lat else None,
        "p99_batch_ms": round(float(np.percentile(lats, 99)) * 1e3, 3)
        if lat else None,
        "key_syncs": sync1 - sync0,
        "macs_verified_server": server_macs,
        "macs_verified_clients": client_macs,
        "all_macs_verified": (not errors and server_macs == sent
                              and client_macs == sent),
        "rejected": stats1["rejected"] - stats0["rejected"],
    }


def sweep_batch(transports: List[str], total_msgs: int, infer_msgs: int,
                engine_service) -> List[Dict]:
    """Lockstep baseline + batched cells per transport (and the engine
    service's native batch path when available)."""
    results = []
    for name in transports:
        gw = ServiceGateway(name, max_keys=256)
        gw.register_service("wordcount", wordcount_handler)
        if engine_service is not None:
            gw.register_service("infer", engine_service.handler,
                                batch_handler=engine_service.handler_batch)
        gw.start()
        try:
            cells = [("lockstep", 1)] + [("batched", k)
                                         for k in BATCH_IN_FLIGHT]
            for mode, k in cells:
                cell = run_batch_cell(
                    gw, "wordcount", k, total_msgs,
                    lambda j: make_text(WORDS, seed=j), mode)
                cell["transport"] = name
                results.append(cell)
                print(f"  {name:<12} wordcount {mode:<8} k={k:<3} "
                      f"{cell['throughput_rps']:>9} msg/s "
                      f"syncs={cell['key_syncs']}", flush=True)
                if engine_service is not None:
                    from repro.runtime import encode_prompt
                    cell = run_batch_cell(
                        gw, "infer", k, infer_msgs,
                        lambda j: encode_prompt(
                            [1 + j % 29, 2, 3, 4][:PROMPT_LEN],
                            max_new=MAX_NEW), mode)
                    cell["transport"] = name
                    results.append(cell)
                    print(f"  {name:<12} infer     {mode:<8} k={k:<3} "
                          f"{cell['throughput_rps']:>9} msg/s", flush=True)
        finally:
            gw.close()
    return results


# ---------------------------------------------------------------------------
# zero-copy seal path: ≥64 KiB single-stream, arena vs PR 3 copy pattern
# ---------------------------------------------------------------------------

def digest_handler(req: np.ndarray) -> np.ndarray:
    """Cheap fixed-cost handler for large payloads: a vectorized byte sum,
    so the cell measures the seal/verify/copy path, not handler compute,
    and the response stays one frame row."""
    r = np.asarray(req).reshape(-1).view(np.uint8)
    return np.asarray([int(r.sum(dtype=np.uint64))], np.uint64)


def run_payload_cell(gw: ServiceGateway, nbytes: int, reps: int,
                     zero_copy: bool, in_flight: int = 1) -> Dict:
    """One client, one channel, fixed nbytes payload, with the framing
    layer in zero-copy (arena seal) or legacy (PR 3 concat) mode.
    ``in_flight=1`` is the lockstep call() baseline; ``in_flight=k`` keeps
    k messages in flight per round trip via call_batch — the pipelined
    data-plane operating point, where the per-exchange sync constant is
    amortized and the seal/verify/copy cost is what's measured. The
    framing stats hook yields bytes-copied and concat-calls per request."""
    rng = np.random.default_rng(nbytes)
    payload = rng.integers(0, 256, size=nbytes, dtype=np.int64) \
        .astype(np.uint8)
    client = gw.connect(f"bench-payload-{nbytes}-{zero_copy}-{in_flight}")
    client.open("digest")
    prev = framing.ZERO_COPY
    framing.ZERO_COPY = zero_copy
    try:
        def drive():
            if in_flight == 1:
                client.call("digest", payload)
            else:
                client.call_batch("digest", [payload] * in_flight)
        for _ in range(3):                  # warmup / channel setup
            drive()
        st0 = framing.STATS.snapshot()
        sync0 = getattr(gw.transport, "sync_count", 0)
        lat: List[float] = []
        t0 = time.perf_counter()
        for _ in range(reps):
            tb = time.perf_counter()
            drive()
            lat.append(time.perf_counter() - tb)
        wall = time.perf_counter() - t0
        st1 = framing.STATS.snapshot()
        sync1 = getattr(gw.transport, "sync_count", 0)
    finally:
        framing.ZERO_COPY = prev
    macs = client.macs_verified
    client.close()
    total = reps * in_flight
    lats = np.asarray(sorted(lat))
    return {
        "service": "digest",
        "mode": "zero_copy" if zero_copy else "legacy",
        "payload_bytes": nbytes,
        "in_flight": in_flight,
        "requests": total,
        "seconds": round(wall, 4),
        "throughput_rps": round(total / wall, 2) if wall > 0 else None,
        "mib_per_s": round(total * nbytes / wall / 2**20, 2)
        if wall > 0 else None,
        "p50_ms": round(float(np.percentile(lats, 50)) * 1e3, 3),
        "p99_ms": round(float(np.percentile(lats, 99)) * 1e3, 3),
        "key_syncs": sync1 - sync0,
        "bytes_copied_per_request":
            round((st1["bytes_copied"] - st0["bytes_copied"]) / total),
        "concat_calls_per_request":
            round((st1["concat_calls"] - st0["concat_calls"]) / total, 2),
        "macs_verified_clients": macs,
    }


def sweep_payload(transports: List[str], sizes: List[int],
                  reps: int) -> List[Dict]:
    """legacy cells run the FULL PR 3 data plane — concat copy pattern,
    the PR 3 fast_mac (per-block power recomputation) and the PR 3 fused
    batch MAC, all selected by ``framing.ZERO_COPY=False``; zero_copy
    cells run the arena seal path with the streamlined uint32 streaming
    MAC. The A/B is the whole PR, not just the copy schedule; both planes
    produce bit-identical frames."""
    results = []
    for name in transports:
        for zero_copy in (False, True):
            gw = ServiceGateway(name, max_keys=256)
            gw.register_service("digest", digest_handler)
            gw.start()
            try:
                for nbytes in sizes:
                    for in_flight in (1, PAYLOAD_IN_FLIGHT):
                        cell = run_payload_cell(gw, nbytes, reps, zero_copy,
                                                in_flight)
                        cell["transport"] = name
                        results.append(cell)
                        print(f"  {name:<12} digest {cell['mode']:<9} "
                              f"{nbytes >> 10:>5}KiB k={in_flight} "
                              f"{cell['throughput_rps']:>9} req/s "
                              f"({cell['mib_per_s']} MiB/s) "
                              f"copied/req="
                              f"{cell['bytes_copied_per_request']}",
                              flush=True)
            finally:
                gw.close()
    return results


def payload_speedup(payload_results: List[Dict]) -> Dict[str, Optional[float]]:
    """Zero-copy vs legacy throughput per (transport, size, in-flight)."""
    out = {}
    by = {(r["transport"], r["payload_bytes"], r["in_flight"], r["mode"]): r
          for r in payload_results}
    for (tr, nb, k, mode), r in sorted(by.items()):
        if mode != "zero_copy":
            continue
        base = by.get((tr, nb, k, "legacy"))
        if base and base["throughput_rps"]:
            out[f"{tr}/{nb >> 10}KiB/k{k}"] = round(
                r["throughput_rps"] / base["throughput_rps"], 2)
    return out


# ---------------------------------------------------------------------------
# sharded executor: one client scattering across N services
# ---------------------------------------------------------------------------

def make_micro_handler(i: int, delay: float = SCATTER_DELAY):
    """One 'microservice': a small sleep (modelling downstream I/O — the
    latency a parallel executor can overlap) plus a vectorized digest."""
    def handler(req: np.ndarray) -> np.ndarray:
        time.sleep(delay)
        r = np.asarray(req).reshape(-1).view(np.uint8)
        return np.asarray([int(r.sum(dtype=np.uint64)) + i], np.uint64)
    return handler


def run_scatter_cell(transport: str, workers: int, n_services: int,
                     rounds: int, mode: str) -> Dict:
    """One client fanning one request per service per round. ``sequential``
    issues n_services lockstep call()s (the PR 3 path); ``scatter`` sends
    ONE call_many envelope, executed across the gateway's shards."""
    gw = ServiceGateway(transport, max_keys=256, workers=workers)
    for i in range(n_services):
        gw.register_service(f"svc{i}", make_micro_handler(i))
    gw.start()
    try:
        client = gw.connect(f"bench-scatter-{mode}-{workers}")
        items = [(f"svc{i}", make_text(200, seed=i))
                 for i in range(n_services)]
        for service, p in items:            # warmup + channel setup
            client.call(service, p)
        lat: List[float] = []
        t0 = time.perf_counter()
        for _ in range(rounds):
            tb = time.perf_counter()
            if mode == "sequential":
                for service, p in items:
                    client.call(service, p)
            else:
                client.call_many(items)
            lat.append(time.perf_counter() - tb)
        wall = time.perf_counter() - t0
        total = rounds * n_services
        lats = np.asarray(sorted(lat))
        shard = gw.shard_stats()
        stats = dict(gw.stats)
        client.close()
        return {
            "mode": mode,
            "workers": workers,
            "services": n_services,
            "rounds": rounds,
            "requests": total,
            "seconds": round(wall, 4),
            "throughput_rps": round(total / wall, 2) if wall > 0 else None,
            "p50_round_ms": round(float(np.percentile(lats, 50)) * 1e3, 3),
            "p99_round_ms": round(float(np.percentile(lats, 99)) * 1e3, 3),
            "scatter_envelopes": stats["scatter_envelopes"],
            "rejected": stats["rejected"],
            "shards": shard,
            "transport": transport,
        }
    finally:
        gw.close()


def sweep_scatter(transport: str, n_services: int, rounds: int,
                  workers_list: List[int]) -> List[Dict]:
    cells = [("sequential", 0)] + [("scatter", w) for w in workers_list]
    results = []
    for mode, workers in cells:
        cell = run_scatter_cell(transport, workers, n_services, rounds, mode)
        results.append(cell)
        print(f"  {transport:<12} {mode:<10} workers={workers} "
              f"{cell['throughput_rps']:>9} req/s "
              f"p50={cell['p50_round_ms']}ms/round", flush=True)
    return results


def scatter_speedup(scatter_results: List[Dict]) -> Dict[str, Optional[float]]:
    """Scatter-at-workers vs the sequential-calls baseline."""
    out = {}
    base = next((r for r in scatter_results if r["mode"] == "sequential"),
                None)
    if not base or not base["throughput_rps"]:
        return out
    for r in scatter_results:
        if r["mode"] == "scatter":
            out[f"workers{r['workers']}"] = round(
                r["throughput_rps"] / base["throughput_rps"], 2)
    return out


# ---------------------------------------------------------------------------
# high-fan-in coalescing: N inline clients, auto-batching mux off vs on
# ---------------------------------------------------------------------------

FANIN_CLIENTS = [64, 256]
FANIN_WORDS = 200               # small-RPC regime: ~1.4 KB payloads, the
                                # per-message-overhead-dominated fan-in case
FANIN_MAX_BATCH = 64
FANIN_MAX_WAIT_US = 500.0


def run_fanin_cell(transport: str, n_clients: int, reps: int,
                   coalesce: bool) -> Dict:
    """n_clients caller threads, each its own CA-enrolled GatewayClient,
    all issuing inline call()s. ``coalesce`` flips the gateway's
    auto-batching mux — callers are byte-for-byte identical either way
    (that is the point: the win needs no caller opt-in)."""
    gw = ServiceGateway(transport, max_keys=2048)
    gw.register_service("wordcount", wordcount_handler)
    gw.start()
    mux = (gw.enable_coalescing(max_batch=FANIN_MAX_BATCH,
                                max_wait_us=FANIN_MAX_WAIT_US)
           if coalesce else None)
    clients = [gw.connect(f"fanin-{n_clients}-{int(coalesce)}-{i}")
               for i in range(n_clients)]
    for c in clients:                       # channel setup off the clock;
        c.open("wordcount")                 # inline cells also pre-open
        if not coalesce:                    # their wire sessions
            c._session
    latencies: List[List[float]] = [[] for _ in range(n_clients)]
    errors: List[str] = []
    barrier = threading.Barrier(n_clients + 1)

    def worker(i):
        c = clients[i]
        try:
            barrier.wait()
            for j in range(reps):
                t0 = time.perf_counter()
                c.call("wordcount", make_text(FANIN_WORDS, seed=i * 131 + j))
                latencies[i].append(time.perf_counter() - t0)
        except Exception as e:              # pragma: no cover
            errors.append(repr(e))

    threads = [threading.Thread(target=worker, args=(i,))
               for i in range(n_clients)]
    for t in threads:
        t.start()
    stats0 = dict(gw.stats)
    st0 = framing.STATS.snapshot()
    sync0 = getattr(gw.transport, "sync_count", 0)
    barrier.wait()
    t0 = time.perf_counter()
    for t in threads:
        t.join()
    wall = time.perf_counter() - t0
    stats1 = dict(gw.stats)
    st1 = framing.STATS.snapshot()
    sync1 = getattr(gw.transport, "sync_count", 0)
    client_macs = sum(c.macs_verified for c in clients)
    if mux is not None:
        client_macs += mux._carrier.macs_verified
    mux_stats = dict(mux.stats) if mux is not None else None
    for c in clients:
        c.close()
    gw.close()

    lats = np.asarray(sorted(sum(latencies, [])))
    total = int(lats.size)
    server_macs = stats1["macs_verified"] - stats0["macs_verified"]
    return {
        "service": "wordcount",
        "mode": "coalesced" if coalesce else "inline",
        "clients": n_clients,
        "requests": total,
        "errors": errors,
        "seconds": round(wall, 4),
        "throughput_rps": round(total / wall, 2) if wall > 0 else None,
        "p50_ms": round(float(np.percentile(lats, 50)) * 1e3, 3)
        if total else None,
        "p99_ms": round(float(np.percentile(lats, 99)) * 1e3, 3)
        if total else None,
        "key_syncs": sync1 - sync0,
        "syncs_per_request": round((sync1 - sync0) / total, 3)
        if total else None,
        "wakeups_per_request":
            round((st1["wakeups"] - st0["wakeups"]) / total, 3)
            if total else None,
        "doorbell_parks_per_request":
            round((st1["doorbell_parks"] - st0["doorbell_parks"]) / total, 3)
            if total else None,
        "macs_verified_server": server_macs,
        "macs_verified_clients": client_macs,
        "all_macs_verified": (not errors and server_macs == total
                              and client_macs == total),
        "rejected": stats1["rejected"] - stats0["rejected"],
        "coalescer": mux_stats,
        "transport": transport,
    }


def sweep_fanin(transports: List[str], clients_list: List[int],
                reps_by_count: Dict[int, int]) -> List[Dict]:
    results = []
    for name in transports:
        for n in clients_list:
            for coalesce in (False, True):
                cell = run_fanin_cell(name, n, reps_by_count[n], coalesce)
                results.append(cell)
                print(f"  {name:<12} fanin {cell['mode']:<9} c={n:<4} "
                      f"{cell['throughput_rps']:>9} req/s "
                      f"p50={cell['p50_ms']}ms "
                      f"wakeups/req={cell['wakeups_per_request']} "
                      f"syncs/req={cell['syncs_per_request']}", flush=True)
    return results


def fanin_speedup(fanin_results: List[Dict]) -> Dict[str, Optional[float]]:
    """Coalesced vs inline rps (and wakeup reduction) per transport/count."""
    out: Dict[str, Optional[float]] = {}
    by = {(r["transport"], r["clients"], r["mode"]): r for r in fanin_results}
    for (tr, n, mode), r in sorted(by.items()):
        if mode != "coalesced":
            continue
        base = by.get((tr, n, "inline"))
        if base and base["throughput_rps"]:
            out[f"{tr}/{n}c"] = round(
                r["throughput_rps"] / base["throughput_rps"], 2)
        # explicit None checks: a coalesced cell whose wakeups/request
        # ROUNDS to 0.0 is perfect amortization, not a missing ratio —
        # clamp the denominator instead of dropping the key (which would
        # fail the ≥4x gate on the best possible result)
        if (base is not None
                and base.get("wakeups_per_request") is not None
                and r.get("wakeups_per_request") is not None):
            out[f"{tr}/{n}c_wakeup_reduction"] = round(
                base["wakeups_per_request"]
                / max(r["wakeups_per_request"], 1e-3), 2)
    return out


def batch_speedup(batch_results: List[Dict]) -> Dict[str, Optional[float]]:
    """Batched 16-in-flight vs lockstep 1-in-flight throughput per
    (transport, service) — the pipelining payoff."""
    out = {}
    by = {(r["transport"], r["service"], r["mode"], r["in_flight"]): r
          for r in batch_results}
    for (tr, svc, mode, k), r in sorted(by.items()):
        if mode != "batched" or k != 16:
            continue
        base = by.get((tr, svc, "lockstep", 1))
        if base and base["throughput_rps"]:
            out[f"{tr}/{svc}"] = round(
                r["throughput_rps"] / base["throughput_rps"], 2)
    return out


def scaling_summary(results: List[Dict]) -> Dict[str, Optional[float]]:
    """16-client vs 1-client aggregate throughput per (transport, service)."""
    out = {}
    by = {(r["transport"], r["service"], r["clients"]): r for r in results}
    for (tr, svc, n), r in sorted(by.items()):
        if n != 16:
            continue
        base = by.get((tr, svc, 1))
        if base and base["throughput_rps"]:
            out[f"{tr}/{svc}"] = round(
                r["throughput_rps"] / base["throughput_rps"], 2)
    return out


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true",
                    help="mpklink variants only, clients ≤ 16, fewer reps")
    ap.add_argument("--no-infer", action="store_true",
                    help="skip the ServingEngine-backed service")
    ap.add_argument("--no-batch", action="store_true",
                    help="skip the pipelined batch sweep")
    ap.add_argument("--no-payload", action="store_true",
                    help="skip the zero-copy large-payload sweep")
    ap.add_argument("--no-scatter", action="store_true",
                    help="skip the sharded-executor scatter sweep")
    ap.add_argument("--no-fanin", action="store_true",
                    help="skip the high-fan-in coalescing sweep")
    ap.add_argument("--out", default=None, help="write JSON here too")
    args = ap.parse_args()

    transports = (["mpklink", "mpklink_opt"] if args.quick
                  else TRANSPORTS_ORDER)
    clients = [c for c in CLIENTS if c <= (16 if args.quick else 64)]
    reps_wc = 4 if args.quick else 8
    reps_inf = 2 if args.quick else 6
    batch_msgs = 32 if args.quick else 64
    infer_msgs = 8 if args.quick else 16
    batch_transports = (["mpklink_opt"] if args.quick
                        else ["mpklink", "mpklink_opt"])
    payload_sizes = PAYLOAD_SIZES[:2] if args.quick else PAYLOAD_SIZES
    payload_reps = 6 if args.quick else 12
    payload_transports = (["mpklink_opt"] if args.quick
                          else ["mpklink", "mpklink_opt"])
    scatter_rounds = 12 if args.quick else 30
    scatter_workers = [0, 4]
    fanin_clients = [64] if args.quick else FANIN_CLIENTS
    fanin_reps = {64: 3, 256: 2} if args.quick else {64: 8, 256: 4}

    engine_service = None if args.no_infer else build_engine_service()
    try:
        results = sweep(transports, clients, reps_wc, reps_inf, engine_service)
        batch_results = ([] if args.no_batch else
                         sweep_batch(batch_transports, batch_msgs,
                                     infer_msgs, engine_service))
    finally:
        if engine_service is not None:
            engine_service.close()
    payload_results = ([] if args.no_payload else
                       sweep_payload(payload_transports, payload_sizes,
                                     payload_reps))
    scatter_results = ([] if args.no_scatter else
                       sweep_scatter("mpklink_opt", SCATTER_SERVICES,
                                     scatter_rounds, scatter_workers))
    fanin_results = ([] if args.no_fanin else
                     sweep_fanin(["mpklink_opt"], fanin_clients, fanin_reps))

    speedup = batch_speedup(batch_results)
    zc_speedup = payload_speedup(payload_results)
    sc_speedup = scatter_speedup(scatter_results)
    fi_speedup = fanin_speedup(fanin_results)
    # gate on the pipelined operating point (k>1): one client, one channel,
    # k in flight — the data plane whose copies/MACs this PR optimized; the
    # k=1 lockstep cells are reported for transparency (dominated by the
    # per-exchange sync constant both modes share)
    opt_zc = [v for k, v in zc_speedup.items()
              if k.startswith("mpklink_opt/")
              and k.endswith(f"/k{PAYLOAD_IN_FLIGHT}")]
    report = {
        "meta": {"clients": clients, "transports": transports,
                 "wordcount_words": WORDS, "prompt_len": PROMPT_LEN,
                 "max_new": MAX_NEW, "batch_in_flight": BATCH_IN_FLIGHT,
                 "batch_msgs": batch_msgs, "payload_sizes": payload_sizes,
                 "scatter_services": SCATTER_SERVICES,
                 "scatter_delay_s": SCATTER_DELAY,
                 "scatter_workers": scatter_workers,
                 "fanin_clients": fanin_clients,
                 "fanin_words": FANIN_WORDS,
                 "fanin_max_batch": FANIN_MAX_BATCH,
                 "fanin_max_wait_us": FANIN_MAX_WAIT_US},
        "results": results,
        "scaling_16c_over_1c": scaling_summary(results),
        "batch_results": batch_results,
        "batch_speedup_16_over_lockstep": speedup,
        "batch_gate_mpklink_opt_2x": (
            None if not batch_results
            else speedup.get("mpklink_opt/wordcount", 0) >= 2.0),
        "payload_results": payload_results,
        "zero_copy_speedup": zc_speedup,
        "zero_copy_gate_mpklink_opt_1p5x": (
            None if not payload_results
            else bool(opt_zc) and min(opt_zc) >= 1.5),
        "scatter_results": scatter_results,
        "scatter_speedup_vs_sequential": sc_speedup,
        "scatter_gate_workers4_2x": (
            None if not scatter_results
            else sc_speedup.get("workers4", 0) >= 2.0),
        "fanin_results": fanin_results,
        "fanin_speedup_coalesced_over_inline": fi_speedup,
        "coalesce_gate_mpklink_opt_64c_2x": (
            None if not fanin_results
            else fi_speedup.get("mpklink_opt/64c", 0) >= 2.0),
        "coalesce_wakeup_gate_4x": (
            None if not fanin_results
            else fi_speedup.get("mpklink_opt/64c_wakeup_reduction", 0)
            >= 4.0),
        "all_macs_verified": all(r["all_macs_verified"]
                                 for r in results + batch_results
                                 + fanin_results),
    }
    blob = json.dumps(report, indent=2)
    print(blob)
    if args.out:
        with open(args.out, "w") as f:
            f.write(blob)
    # gates hard-fail only on full runs (the committed-artifact path);
    # --quick sweeps use too few reps to enforce perf ratios on a noisy
    # runner — they still REPORT the gates, and benchmarks/perf_gate.py
    # guards regressions against the committed ratios with tolerance
    if not args.quick:
        for gate in ("batch_gate_mpklink_opt_2x",
                     "zero_copy_gate_mpklink_opt_1p5x",
                     "scatter_gate_workers4_2x",
                     "coalesce_gate_mpklink_opt_64c_2x",
                     "coalesce_wakeup_gate_4x"):
            if report[gate] is False:
                raise SystemExit(f"gate failed: {gate}")
    return report


if __name__ == "__main__":
    main()
