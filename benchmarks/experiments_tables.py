"""Generate the EXPERIMENTS.md §Dry-run / §Roofline markdown tables from
artifacts/dryrun/*.json (regenerate after any sweep)."""
from __future__ import annotations

import glob
import json
import os

ARTIFACTS = os.path.join(os.path.dirname(__file__), "..", "artifacts", "dryrun")


def load():
    rows = []
    for path in sorted(glob.glob(os.path.join(ARTIFACTS, "*.json"))):
        with open(path) as f:
            rows.append(json.load(f))
    return rows


def fmt_bytes(b):
    if b is None:
        return "—"
    return f"{b/2**30:.2f}"


def dryrun_table(rows):
    out = ["| arch | shape | mesh | compile(s) | peak GiB/dev | HLO flops/dev | coll bytes/dev | #coll |",
           "|---|---|---|---:|---:|---:|---:|---:|"]
    for r in rows:
        if r.get("opts", "base") != "base" or r["status"] != "ok":
            continue
        rf = r["roofline"]
        out.append(
            f"| {r['arch']} | {r['shape']} | {r['mesh']} | {r['compile_s']:.1f} "
            f"| {fmt_bytes(r['memory'].get('peak_bytes'))} "
            f"| {rf['flops']:.2e} | {rf['collective_bytes']:.2e} "
            f"| {rf['n_collectives']} |")
    return "\n".join(out)


def roofline_table(rows, mesh="16x16"):
    out = [("| arch | shape | t_compute(s) | t_memory(s) | t_collective(s) | "
            "bound | frac | useful | next move |"),
           "|---|---|---:|---:|---:|---|---:|---:|---|"]
    moves = {
        ("compute",): "raise arithmetic intensity / cut redundant compute",
        ("memory",): "bigger flash blocks; Pallas kernel keeps acc in VMEM",
        ("collective",): "fewer/batched exchanges (fabric), compression",
    }
    for r in rows:
        if r.get("opts", "base") != "base" or r["status"] != "ok" \
                or r["mesh"] != mesh:
            continue
        rf = r["roofline"]
        t = (rf["t_compute_s"], rf["t_memory_s"], rf["t_collective_s"])
        s = sum(t)
        frac = max(t) / s if s else 0
        out.append(
            f"| {r['arch']} | {r['shape']} | {t[0]:.3f} | {t[1]:.3f} "
            f"| {t[2]:.3f} | {rf['bottleneck']} | {frac:.2f} "
            f"| {r.get('useful_flops_ratio') or 0:.3f} "
            f"| {moves[(rf['bottleneck'],)]} |")
    return "\n".join(out)


def skips():
    out = []
    from repro.configs import all_cells
    for arch, shape, ok, why in all_cells():
        if not ok:
            out.append(f"- `{arch}` × `{shape.name}`: {why}")
    return "\n".join(out)


if __name__ == "__main__":
    rows = load()
    print("## Dry-run table\n")
    print(dryrun_table(rows))
    print("\n## Roofline (single-pod)\n")
    print(roofline_table(rows, "16x16"))
    print("\n## Roofline (multi-pod)\n")
    print(roofline_table(rows, "2x16x16"))
    print("\n## Skips\n")
    print(skips())
