"""Perf-regression gate: quick re-measurement vs the committed JSON.

Run by the CI ``perf`` job (and by hand before regenerating the committed
artifacts):

    PYTHONPATH=src python benchmarks/perf_gate.py [--tolerance 0.20]

Re-measures the gated mpklink_opt cells of gateway_bench with short
sweeps and fails (exit 1) when throughput regresses more than the
tolerance (default 20%) against ``benchmarks/results/gateway_bench.json``,
and re-measures the process-backed baseline fight (mpklink_opt_proc vs
loopback REST at 16 clients) against
``benchmarks/results/ipc_baseline_bench.json`` the same way.

Comparisons are made on machine-independent SPEEDUP RATIOS — zero-copy vs
the PR 3 legacy plane at the pipelined operating point, the sharded
scatter executor vs sequential calls, and the auto-coalescing mux vs
inline high-fan-in calls — not on absolute req/s, because CI runners and
the machine that produced the committed JSON differ in absolute speed
while the ratios are properties of the code. The coalescing wakeup
reduction is a COUNT ratio (doorbell rings per request), so it is gated
absolutely (≥ 4×), not tolerance-relative. The committed JSON's own
boolean gates are re-asserted as well, so a regenerated artifact that
fails its acceptance claims cannot be committed silently.
``PERF_GATE_TOLERANCE`` overrides the tolerance for noisy runners.
"""
from __future__ import annotations

import argparse
import json
import os
import sys
from pathlib import Path

sys.path.insert(0, str(Path(__file__).resolve().parent))

from gateway_bench import (PAYLOAD_IN_FLIGHT, fanin_speedup,          # noqa: E402
                           payload_speedup, scatter_speedup, sweep_fanin,
                           sweep_payload, sweep_scatter)
from ipc_baseline_bench import (GATE_ATTEMPTS, GATE_CLIENTS,          # noqa: E402
                                baseline_ratio, run_cell)
import fleet_bench                                                    # noqa: E402
import qos_bench                                                      # noqa: E402

COMMITTED = Path(__file__).resolve().parent / "results" / "gateway_bench.json"
IPC_COMMITTED = (Path(__file__).resolve().parent
                 / "results" / "ipc_baseline_bench.json")
IPC_GATE = "mpklink_opt_proc_2x_rest_16c"
IPC_RATIO = "mpklink_opt_proc_vs_rest_rps_ratio_16c"
IPC_FRESH_N_PER_CLIENT = 25         # 400 requests per cell: short re-measure

FLEET_COMMITTED = (Path(__file__).resolve().parent
                   / "results" / "fleet_bench.json")
FLEET_RATIO = "fleet_4r_vs_1r_rps_ratio_poisson"
# committed fleet booleans that must still hold (see fleet_bench.py)
FLEET_GATES = ("all_answers_correct", "no_lost_requests",
               "kill_cell_zero_lost", "kill_victim_marked_dead",
               "fleet_4r_2x_1r_poisson",
               # self-healing: kill -9 x2 during a live scale event under a
               # FleetSupervisor, plus the late-binding hedge pair
               "midscale_zero_lost", "midscale_capacity_restored",
               "midscale_respawns_cover_kills", "midscale_70pct_throughput",
               "hedged_p99_le_unhedged", "hedge_executed_count_unchanged")
FLEET_FRESH_CLIENTS = 64            # quick fresh re-measure of the ratio
FLEET_FRESH_REQUESTS = 320

QOS_COMMITTED = (Path(__file__).resolve().parent
                 / "results" / "qos_bench.json")
# committed noisy-neighbor booleans that must still hold (qos_bench.py)
QOS_GATES = ("victim_p99_le_2x_solo", "abuser_throughput_le_1p2x_rate",
             "abuser_sheds_typed", "all_answers_correct",
             "no_lost_requests")
QOS_FRESH_N = 60                    # per-victim requests for the re-measure

# the committed boolean acceptance gates that must still hold
GATES = ("batch_gate_mpklink_opt_2x", "zero_copy_gate_mpklink_opt_1p5x",
         "scatter_gate_workers4_2x", "coalesce_gate_mpklink_opt_64c_2x",
         "coalesce_wakeup_gate_4x")

WAKEUP_REDUCTION_FLOOR = 4.0        # absolute count-ratio gate, no tolerance

# each committed gate's underlying ratio: (committed dict, committed cell,
# fresh-sweep key) — so a FAIL names the cell that regressed with both
# numbers instead of just the gate's name
GATE_CELLS = {
    "batch_gate_mpklink_opt_2x":
        ("batch_speedup_16_over_lockstep", "mpklink_opt/wordcount", None),
    "zero_copy_gate_mpklink_opt_1p5x":
        ("zero_copy_speedup", "mpklink_opt/64KiB/k{k}", "zc"),
    "scatter_gate_workers4_2x":
        ("scatter_speedup_vs_sequential", "workers4", "sc"),
    "coalesce_gate_mpklink_opt_64c_2x":
        ("fanin_speedup_coalesced_over_inline", "mpklink_opt/64c", "fi"),
    "coalesce_wakeup_gate_4x":
        ("fanin_speedup_coalesced_over_inline",
         "mpklink_opt/64c_wakeup_reduction", "fi"),
}


def _gate_ratio_pair(gate, committed, fresh_by_sweep):
    """→ 'committed <dict>[<cell>]=<x>, fresh=<y>' for a failed gate."""
    dict_name, cell, sweep = GATE_CELLS.get(gate, (None, None, None))
    if dict_name is None:
        return "no ratio cell mapped"
    cell = cell.format(k=PAYLOAD_IN_FLIGHT)
    base = committed.get(dict_name, {}).get(cell)
    fresh = fresh_by_sweep.get(sweep, {}).get(cell) \
        if sweep is not None else None
    pair = f"committed {dict_name}[{cell}]={base!r}"
    if sweep is not None:
        pair += f", fresh={fresh!r}"
    return pair


def main() -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--tolerance", type=float,
                    default=float(os.environ.get("PERF_GATE_TOLERANCE",
                                                 "0.20")),
                    help="allowed fractional regression vs committed ratios")
    args = ap.parse_args()
    committed = json.loads(COMMITTED.read_text())

    failures = []
    failed_gates = []
    for gate in GATES:
        ok = committed.get(gate) is True
        print(f"committed gate {gate}: {'PASS' if ok else 'FAIL'}")
        if not ok:
            failed_gates.append(gate)

    print("fresh zero-copy sweep (mpklink_opt, 64 KiB):", flush=True)
    fresh_zc = payload_speedup(sweep_payload(["mpklink_opt"], [64 * 1024], 8))
    print("fresh scatter sweep (mpklink_opt, 4 services):", flush=True)
    fresh_sc = scatter_speedup(sweep_scatter("mpklink_opt", 4, 10, [0, 4]))
    print("fresh high-fan-in sweep (mpklink_opt, 64 clients):", flush=True)
    fresh_fi = fanin_speedup(sweep_fanin(["mpklink_opt"], [64], {64: 3}))

    fresh_by_sweep = {"zc": fresh_zc, "sc": fresh_sc, "fi": fresh_fi}
    for gate in failed_gates:
        failures.append(
            f"committed gate {gate} is not true "
            f"({_gate_ratio_pair(gate, committed, fresh_by_sweep)})")

    # single-box throughput ratios carry multiplicative host noise that
    # lands on whichever cell happens to be running, so a reading under
    # the floor is re-measured up to GATE_ATTEMPTS total and judged on the
    # best attempt — the same protocol the ipc/fleet pair gates document
    remeasure = {
        "zc": lambda: payload_speedup(
            sweep_payload(["mpklink_opt"], [64 * 1024], 8)),
        "sc": lambda: scatter_speedup(
            sweep_scatter("mpklink_opt", 4, 10, [0, 4])),
        "fi": lambda: fanin_speedup(
            sweep_fanin(["mpklink_opt"], [64], {64: 3})),
    }
    checks = [
        (f"zero_copy_speedup[mpklink_opt/64KiB/k{PAYLOAD_IN_FLIGHT}]",
         "zc", f"mpklink_opt/64KiB/k{PAYLOAD_IN_FLIGHT}",
         committed.get("zero_copy_speedup", {})
         .get(f"mpklink_opt/64KiB/k{PAYLOAD_IN_FLIGHT}")),
        ("scatter_speedup_vs_sequential[workers4]",
         "sc", "workers4",
         committed.get("scatter_speedup_vs_sequential", {}).get("workers4")),
        ("fanin_speedup_coalesced_over_inline[mpklink_opt/64c]",
         "fi", "mpklink_opt/64c",
         committed.get("fanin_speedup_coalesced_over_inline", {})
         .get("mpklink_opt/64c")),
    ]
    for name, sweep, cell, base in checks:
        if base is None:
            failures.append(f"{name}: missing from committed JSON")
            continue
        floor = (1.0 - args.tolerance) * base
        fresh = fresh_by_sweep[sweep].get(cell)
        attempt = 1
        while ((fresh is None or fresh < floor)
               and attempt < GATE_ATTEMPTS):
            attempt += 1
            print(f"{name}: {fresh} under floor {floor:.2f} — "
                  f"re-measuring (attempt {attempt})", flush=True)
            fresh_by_sweep[sweep] = remeasure[sweep]()
            v = fresh_by_sweep[sweep].get(cell)
            if v is not None and (fresh is None or v > fresh):
                fresh = v
        if fresh is None:
            failures.append(f"{name}: fresh measurement missing")
            continue
        ok = fresh >= floor
        print(f"{name}: fresh(best)={fresh} committed={base} "
              f"floor={floor:.2f} -> {'PASS' if ok else 'FAIL'}")
        if not ok:
            failures.append(
                f"{name} regressed >{args.tolerance:.0%}: "
                f"fresh best {fresh} < floor {floor:.2f} (committed {base})")

    # the wakeup reduction is a deterministic count ratio: gate absolutely
    wred = fresh_by_sweep["fi"].get("mpklink_opt/64c_wakeup_reduction")
    ok = wred is not None and wred >= WAKEUP_REDUCTION_FLOOR
    print(f"fanin wakeup reduction [mpklink_opt/64c]: fresh={wred} "
          f"floor={WAKEUP_REDUCTION_FLOOR} -> {'PASS' if ok else 'FAIL'}")
    if not ok:
        failures.append(
            f"coalescing wakeup reduction {wred} below the "
            f"{WAKEUP_REDUCTION_FLOOR}x floor")

    # -- process-backed baseline fight (ipc_baseline_bench) ----------------
    # same interleaved-pair / best-attempt protocol as the bench itself:
    # host noise is multiplicative on whichever cell is running, so the
    # best paired ratio is the least-contaminated estimate
    ipc = json.loads(IPC_COMMITTED.read_text())
    ipc_gates = ipc.get("gates", {})
    for g in ("all_answers_correct", "no_client_errors", IPC_GATE):
        ok = ipc_gates.get(g) is True
        print(f"committed ipc gate {g}: {'PASS' if ok else 'FAIL'}")
        if not ok:
            failures.append(
                f"committed ipc gate {g} is not true (committed "
                f"{IPC_RATIO}={ipc_gates.get(IPC_RATIO)!r})")
    base = ipc_gates.get(IPC_RATIO)
    if base is None:
        failures.append(f"{IPC_RATIO}: missing from committed JSON")
    else:
        floor = (1.0 - args.tolerance) * base
        best = None
        for attempt in range(GATE_ATTEMPTS):
            pair = [run_cell(n, GATE_CLIENTS, IPC_FRESH_N_PER_CLIENT)
                    for n in ("mpklink_opt_proc", "rest")]
            r = baseline_ratio(pair)
            print(f"fresh ipc baseline pair {attempt}: "
                  f"mpk {pair[0]['throughput_rps']} rest "
                  f"{pair[1]['throughput_rps']} ratio={r}", flush=True)
            if r is not None and (best is None or r > best):
                best = r
            if best is not None and best >= floor:
                break
        ok = best is not None and best >= floor
        print(f"{IPC_RATIO}: fresh(best)={best} committed={base} "
              f"floor={floor:.2f} -> {'PASS' if ok else 'FAIL'}")
        if not ok:
            failures.append(
                f"{IPC_RATIO} regressed >{args.tolerance:.0%}: "
                f"fresh best {best} < floor {floor:.2f} (committed {base})")

    # -- replica fleet (fleet_bench) ---------------------------------------
    fleet = json.loads(FLEET_COMMITTED.read_text())
    fleet_gates = fleet.get("gates", {})
    for g in FLEET_GATES:
        ok = fleet_gates.get(g) is True
        print(f"committed fleet gate {g}: {'PASS' if ok else 'FAIL'}")
        if not ok:
            failures.append(
                f"committed fleet gate {g} is not true (committed "
                f"{FLEET_RATIO}={fleet_gates.get(FLEET_RATIO)!r})")
    base = fleet_gates.get(FLEET_RATIO)
    if base is None:
        failures.append(f"{FLEET_RATIO}: missing from committed JSON")
    else:
        floor = (1.0 - args.tolerance) * base
        best = None
        for attempt in range(GATE_ATTEMPTS):
            pair = [fleet_bench.run_cell(r, FLEET_FRESH_CLIENTS,
                                         FLEET_FRESH_REQUESTS, "poisson")
                    for r in (1, 4)]
            r = fleet_bench.fleet_ratio(pair, FLEET_FRESH_CLIENTS)
            print(f"fresh fleet pair {attempt}: 1r "
                  f"{pair[0]['throughput_rps']} 4r "
                  f"{pair[1]['throughput_rps']} ratio={r}", flush=True)
            if r is not None and (best is None or r > best):
                best = r
            if best is not None and best >= floor:
                break
        ok = best is not None and best >= floor
        print(f"{FLEET_RATIO}: fresh(best)={best} committed={base} "
              f"floor={floor:.2f} -> {'PASS' if ok else 'FAIL'}")
        if not ok:
            failures.append(
                f"{FLEET_RATIO} regressed >{args.tolerance:.0%}: "
                f"fresh best {best} < floor {floor:.2f} (committed {base})")

    # fresh supervised mid-scale-event chaos cell: kill -9 during a live
    # scale event must still lose nothing and heal back to target. The
    # throughput-ratio gate stays on the committed full-size run (a
    # FLEET_FRESH_REQUESTS-sized schedule is too short for a stable
    # ratio); this re-assert checks the correctness/healing booleans.
    ok = False
    for attempt in range(GATE_ATTEMPTS):
        cell = fleet_bench._midscale_cell(FLEET_FRESH_CLIENTS,
                                          FLEET_FRESH_REQUESTS)
        sup = cell["supervisor"] or {}
        healed = (cell["capacity_active"] == 4
                  and sup.get("respawns", 0) >= cell["kills"] >= 1)
        print(f"fresh midscale cell {attempt}: lost={len(cell['lost'])} "
              f"wrong={cell['wrong_answers']} kills={cell['kills']} "
              f"active={cell['capacity_active']} "
              f"respawns={sup.get('respawns')}", flush=True)
        if not cell["lost"] and not cell["wrong_answers"] and healed:
            ok = True
            break
    print(f"fresh midscale chaos cell: {'PASS' if ok else 'FAIL'}")
    if not ok:
        failures.append(
            "fresh supervised midscale cell failed: lost requests, wrong "
            "answers, or the fleet did not heal back to target")

    # -- multi-tenant QoS noisy neighbor (qos_bench) -----------------------
    qos = json.loads(QOS_COMMITTED.read_text())
    qos_gates = qos.get("gates", {})
    for g in QOS_GATES:
        ok = qos_gates.get(g) is True
        print(f"committed qos gate {g}: {'PASS' if ok else 'FAIL'}")
        if not ok:
            failures.append(
                f"committed qos gate {g} is not true (committed victim "
                f"ratio={qos_gates.get('victim_p99_ratio_vs_solo')!r}, "
                f"abuser ratio="
                f"{qos_gates.get('abuser_throughput_ratio_vs_rate')!r})")
    # fresh paired re-measure: the victim-p99 and abuser-throughput ratios
    # are already machine-independent multiples with documented headroom,
    # so they are gated absolutely at the bench's own floors (best paired
    # attempt — single-box noise is multiplicative)
    ok = False
    best_v = best_a = None
    for attempt in range(GATE_ATTEMPTS):
        solo = qos_bench.run_cell(1, QOS_FRESH_N)
        noisy = qos_bench.run_cell(qos_bench.VICTIMS, QOS_FRESH_N,
                                   abuser=True, limit=True)
        v = qos_bench.victim_ratio(solo, noisy)
        a = qos_bench.abuser_ratio(noisy)
        print(f"fresh qos pair {attempt}: victim p99 ratio={v} "
              f"abuser throughput ratio={a} "
              f"sheds={noisy['abuser_rate_limited']}", flush=True)
        if best_v is None or (v is not None and v < best_v):
            best_v, best_a = v, a
        if (best_v is not None and best_v <= qos_bench.VICTIM_P99_MULT
                and best_a <= qos_bench.ABUSER_TPUT_MULT
                and noisy["abuser_rate_limited"] > 0
                and not noisy["lost"] and not solo["lost"]):
            ok = True
            break
    print(f"fresh qos noisy-neighbor pair: victim(best)={best_v} "
          f"(floor {qos_bench.VICTIM_P99_MULT}) abuser={best_a} "
          f"(floor {qos_bench.ABUSER_TPUT_MULT}) -> "
          f"{'PASS' if ok else 'FAIL'}")
    if not ok:
        failures.append(
            f"fresh qos pair failed: victim p99 ratio {best_v} (must be <= "
            f"{qos_bench.VICTIM_P99_MULT}) or abuser throughput ratio "
            f"{best_a} (must be <= {qos_bench.ABUSER_TPUT_MULT})")

    if failures:
        print("PERF GATE FAILED:")
        for f in failures:
            print(f"  - {f}")
        return 1
    print("perf gate passed")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
