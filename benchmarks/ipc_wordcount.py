"""Paper reproduction benchmark: the distributed word count over the six
registered IPC transports (Fig. 1, Fig. 2, Fig. 3 and Table I of the paper;
mpklink_opt is the beyond-paper sixth).

Measured end-to-end request→count→response latency on this host's CPU —
absolute numbers differ from the paper's Cloudlab c6420 node, but every
qualitative claim is checked in-code (see `validate_claims`).

Default sweep caps at 1e6 words (seconds per point on one core); pass
--full for the paper's 1e8 endpoint.
"""
from __future__ import annotations

import argparse
import time
from typing import Dict, List, Optional

import numpy as np

from repro.core import TRANSPORTS
from repro.core.transports import CapacityError
from repro.core.wordcount import make_text, parse_count, wordcount_handler

WORD_COUNTS = [100, 1_000, 10_000, 100_000, 1_000_000]
WORD_COUNTS_FULL = WORD_COUNTS + [10_000_000, 100_000_000]
ORDER = ["pipe", "uds", "shm", "grpc_sim", "mpklink", "mpklink_opt"]


def measure(name: str, n_words: int, reps: int = 3) -> Optional[float]:
    """Median round-trip seconds, or None if the transport fails (shm cap)."""
    tr = TRANSPORTS[name](wordcount_handler)
    tr.start()
    try:
        text = make_text(n_words, seed=n_words % 97)
        ts = []
        for _ in range(reps):
            t0 = time.perf_counter()
            resp = tr.request(text)
            ts.append(time.perf_counter() - t0)
            assert parse_count(np.asarray(resp)) == n_words
        return sorted(ts)[len(ts) // 2]
    except CapacityError:
        return None
    finally:
        tr.close()


def sweep(full: bool = False, reps: int = 3) -> Dict[str, Dict[int, Optional[float]]]:
    counts = WORD_COUNTS_FULL if full else WORD_COUNTS
    out: Dict[str, Dict[int, Optional[float]]] = {}
    for name in ORDER:
        out[name] = {}
        for n in counts:
            reps_n = reps if n <= 1_000_000 else 1
            out[name][n] = measure(name, n, reps_n)
    return out


def validate_claims(results) -> List[str]:
    """Check the paper's qualitative claims against measured data
    (DESIGN.md §8). Returns a list of 'claim: PASS/FAIL' lines."""
    lines = []
    mpk = results["mpklink"]
    pipe = results["pipe"]
    shm = results["shm"]
    uds = results["uds"]

    c1 = mpk[100] is not None and pipe[100] is not None and \
        mpk[100] < pipe[100] * 1.5
    note = "" if c1 else \
        " — ENV-DEPENDENT: the paper spin-polls its PKRU sync region " \
        "(32-core Cloudlab node); this 1-core container must use event " \
        "wakeups (~100µs each), which inverts the fixed-cost comparison " \
        "at tiny payloads. See EXPERIMENTS.md §Repro deviations."
    lines.append(f"claim1 (MPKLink competitive with pipes at ≤100 words): "
                 f"{'PASS' if c1 else 'DEVIATION'} "
                 f"(mpk={mpk[100]:.2e}s pipe={pipe[100]:.2e}s){note}")

    small = [n for n in mpk if n <= 10_000 and shm[n] is not None]
    c2 = all(mpk[n] >= min(shm[n], uds[n]) * 0.8 for n in small)
    lines.append(f"claim2 (shm/UDS faster than MPKLink at small sizes): "
                 f"{'PASS' if c2 else 'FAIL'}")

    c3 = shm[100_000] is None
    lines.append(f"claim3 (raw shm incapable of ≥100k words): "
                 f"{'PASS' if c3 else 'FAIL'}")

    c4 = mpk[100_000] is not None
    lines.append(f"claim4 (MPKLink handles ≥100k words): "
                 f"{'PASS' if c4 else 'FAIL'}")

    # claim 5 is evaluated in the SYNC-BOUND regime (1e5–1e6 words): there
    # the per-chunk key sync is a measurable fraction of the round trip.
    # At ≥1e7 words the authenticated-copy bandwidth dominates both
    # variants — the sync schedule stops mattering (EXPERIMENTS.md §Repro:
    # a refinement of the paper's attribution of its cliff to key sync).
    # Re-measured here with 9 reps: single-core medians-of-3 flip on noise.
    t_chunked = measure("mpklink", 1_000_000, reps=9)
    t_batched = measure("mpklink_opt", 1_000_000, reps=9)
    c5 = t_batched is not None and t_chunked is not None and \
        t_batched < t_chunked
    lines.append(f"claim5 (beyond-paper: batched key sync beats per-chunk sync "
                 f"in the sync-bound regime, 1e6 words, 9-rep median): "
                 f"{'PASS' if c5 else 'FAIL'} "
                 f"({t_chunked:.4f}s -> {t_batched:.4f}s)")
    return lines


def table_rows(results):
    """CSV rows: figure/table tag, transport, n_words, seconds."""
    rows = []
    for name, series in results.items():
        for n, t in series.items():
            tag = "fig3" if n <= 10_000 else "fig2"
            rows.append((tag, name, n, t))
    # Table I: MPKLink vs best other
    for n in sorted(next(iter(results.values())).keys()):
        others = {k: v[n] for k, v in results.items()
                  if k not in ("mpklink", "mpklink_opt") and v[n] is not None}
        if not others or results["mpklink"][n] is None:
            continue
        best = min(others, key=others.get)
        rows.append(("table1", f"mpklink_vs_{best}", n,
                     results["mpklink"][n] / others[best]))
    return rows


def main(full: bool = False):
    results = sweep(full=full)
    print("figure,transport,n_words,seconds")
    for tag, name, n, t in table_rows(results):
        print(f"{tag},{name},{n},{'' if t is None else f'{t:.6f}'}")
    print()
    for line in validate_claims(results):
        print("#", line)
    return results


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--full", action="store_true",
                    help="sweep to 1e8 words (paper endpoint); slow")
    main(full=ap.parse_args().full)
