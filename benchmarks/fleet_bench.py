"""Replicated serving fleet under open-loop load: 1 vs 4 proc-backed
replicas behind one gateway service name, seeded Poisson and bursty
arrival schedules at 256 clients, SLO-style p50/p99 columns, and a
replica kill -9 mid-run.

The replica handler models a DEVICE-BOUND decode step: it sleeps
``SERVICE_MS`` then echoes (the shape of a serving engine waiting on an
accelerator, where wall-clock service time is real but host CPU is not).
That choice is what makes this bench honest on a single-core runner —
replica parallelism overlaps device waits, which is exactly the resource
fleet scaling buys in production, while a CPU-burning handler could never
scale past 1x on one core no matter how correct the router is. Host-side
per-request work (routing, MAC, rings, proc hops) is real and measured.

Load generation is open loop: a seeded arrival schedule is drawn up
front (Poisson gaps, or bursts of ``BURST`` simultaneous arrivals at the
same mean rate), partitioned round-robin over the client threads, and
each client sleeps until an arrival's scheduled time before issuing it —
so a saturated fleet keeps absorbing offered load it cannot serve, and
p99 shows the queueing honestly. Latency is measured from the SCHEDULED
arrival (slip included), throughput as completed requests over the span
from the first scheduled arrival to the last completion.

Chaos cell: at the schedule midpoint one replica child is SIGKILLed.
Acceptance is zero LOST requests — every scheduled request must end as
either a correct answer or a typed error (ServiceCrashed for the
victim's truly in-flight items); anything else (hang, wrong answer,
untyped exception) is a loss and fails the gate.

Mid-scale-event chaos cell (docs/benchmarks.md): a FleetSupervisor holds
the fleet at target 4 while replica children are SIGKILLed at 50% AND
70% of the schedule — the second kill lands while the supervisor's
release/join step list from the first is still converging, i.e. during a
live scale event. Gates: zero lost, capacity back to target within the
heal window, one respawn per kill, and >= MIDSCALE_FLOOR of the
fault-free throughput sustained.

Hedge pair: the same seeded schedule against a fleet with one
SLOW_FACTOR-slower replica, hedging off vs on (late-binding re-route
after HEDGE_DELAY_S parked). Gates: hedged p99 <= unhedged p99 AND the
executed-request count is unchanged (every completion executed on
exactly one replica — late binding means one wire send ever).

Acceptance gates (exit 1 on violation; CI uses this):
  * 4-replica Poisson at 256 clients sustains >= 2x the 1-replica rps
    (best paired attempt out of up to GATE_ATTEMPTS, same interleaved
    protocol as ipc_baseline_bench — single-box noise is multiplicative);
  * the kill -9 run completes with zero lost requests;
  * the mid-scale-event run: zero lost, capacity restored, >= 70% rps;
  * hedging improves p99 without raising the executed-request count;
  * every answered request is bit-correct.

``--clients 1024`` appends the ROADMAP upper-sweep cells (4 replicas,
Poisson) at the given client counts to the report under
``client_sweep`` — recorded, not gated (the committed JSON carries the
reference-box sweep; CI's default gates exclude it).

  PYTHONPATH=src python benchmarks/fleet_bench.py [--quick] [--out f.json]
      [--clients 256,1024]
"""
from __future__ import annotations

import argparse
import gc
import json
import os
import signal
import threading
import time
from typing import Dict, List, Optional

import numpy as np

from repro.core.gateway import (REPLICA_ACTIVE, FleetSupervisor,
                                RetryBudget, ServiceGateway)
from repro.core.transports import (ResponseTimeout, ServiceCrashed,
                                   ServiceUnavailable)

SERVICE_MS = 8.0                    # device-bound decode model (sleep)
CLIENTS = 256                       # open-loop client threads
TOTAL_REQUESTS = 1200               # per cell
OFFERED_RPS = 420.0                 # ~3.5x one replica's ~115 rps capacity,
                                    # comfortably under 4 replicas' ceiling
BURST = 32                          # bursty profile: simultaneous arrivals
REPLICA_COUNTS = (1, 4)
TIMEOUT = 60.0                      # generous: saturation is the point
GATE_CLIENTS = CLIENTS
GATE_FLOOR = 2.0                    # 4r >= 2x 1r rps, Poisson @ 256c
GATE_ATTEMPTS = 3                   # best paired 1r/4r ratio of <= 3 tries
PAYLOAD_BYTES = 64

# mid-scale-event chaos (supervised fleet, repeated kill -9)
MIDSCALE_KILL_AT = (0.5, 0.7)       # schedule fractions of each SIGKILL
MIDSCALE_FLOOR = 0.7                # >= 70% of fault-free rps sustained
SUP_INTERVAL = 0.1                  # supervisor sweep cadence (s)
HEAL_WINDOW_S = 10.0                # capacity must be back within this

# hedge pair (one slow replica, late-binding hedge)
SLOW_FACTOR = 6.0                   # slow replica: SERVICE_MS * factor
HEDGE_DELAY_S = SERVICE_MS / 1e3    # hedge a request parked this long
HEDGE_OFFERED = 0.6 * OFFERED_RPS   # below capacity: tail, not queueing

_REPLICA_KW = {"ring_slots": 2, "timeout": TIMEOUT}


def _decode_handler(tag: int, service_ms: float = SERVICE_MS):
    def handler(req):
        time.sleep(service_ms / 1e3)
        return np.concatenate([np.asarray(req, np.uint8),
                               np.array([tag], np.uint8)])
    return handler


def poisson_schedule(rate_rps: float, n: int, seed: int) -> np.ndarray:
    """Seeded open-loop Poisson arrivals: cumulative exponential gaps."""
    rng = np.random.default_rng(seed)
    return np.cumsum(rng.exponential(1.0 / rate_rps, size=n))


def bursty_schedule(rate_rps: float, n: int, seed: int,
                    burst: int = BURST) -> np.ndarray:
    """Same mean rate, adversarial shape: BURST simultaneous arrivals per
    burst instant, burst instants Poisson at rate/burst."""
    rng = np.random.default_rng(seed)
    groups = -(-n // burst)
    instants = np.cumsum(rng.exponential(burst / rate_rps, size=groups))
    return np.repeat(instants, burst)[:n]


def _fleet_gateway(replicas: int, clients: int,
                   slow_rid: Optional[int] = None) -> ServiceGateway:
    gw = ServiceGateway("mpklink_opt", max_keys=2 * clients + 64,
                        transport_kwargs={"timeout": TIMEOUT})
    for i in range(replicas):
        ms = SERVICE_MS * (SLOW_FACTOR if i == slow_rid else 1.0)
        gw.register_replica("decode", _decode_handler(i, ms),
                            transport_kwargs=dict(_REPLICA_KW))
    return gw.start()


def run_cell(replicas: int, clients: int, n: int, profile: str, *,
             seed: int = 0xF1EE7, kill_rid: Optional[int] = None,
             kill_at: Optional[tuple] = None,
             supervise: Optional[int] = None,
             hedge: Optional[dict] = None,
             slow_rid: Optional[int] = None,
             offered_rps: float = OFFERED_RPS) -> Dict:
    """One fleet size × one arrival profile → metrics dict. With
    ``kill_rid`` set, that replica's child is SIGKILLed at the schedule
    midpoint (forced-fork warmup guarantees there is a child to kill).
    ``kill_at`` SIGKILLs a currently-active forked replica at each given
    schedule fraction (victims chosen live — under a supervisor the rid
    set changes); ``supervise`` runs a FleetSupervisor at that target and
    waits up to HEAL_WINDOW_S post-run for capacity to converge;
    ``hedge`` enables late-binding hedging with those kwargs;
    ``slow_rid`` makes that replica SLOW_FACTOR× slower."""
    schedule = (poisson_schedule if profile == "poisson"
                else bursty_schedule)(offered_rps, n, seed)
    payload = np.frombuffer(os.urandom(PAYLOAD_BYTES), np.uint8)
    gw = _fleet_gateway(replicas, clients, slow_rid)
    fleet = gw.fleet("decode")
    budget = None
    if hedge is not None:
        budget = fleet.enable_hedging(**hedge)
    sup = None
    if supervise is not None:
        sup = FleetSupervisor(gw, "decode", supervise,
                              interval=SUP_INTERVAL, probe_timeout=2.0)
    lock = threading.Lock()
    ok: List[float] = []            # completion-time latencies (s)
    post_kill_ok: List[float] = []
    typed: List[str] = []
    lost: List[str] = []
    wrong = [0]
    last_done = [0.0]
    killed_at = [None]
    barrier = threading.Barrier(clients + 1)

    def worker(idx: int, t0: float):
        cli = gw.connect(f"lg-{idx}")
        try:
            barrier.wait()
            for k in range(idx, n, clients):
                target = t0 + schedule[k]
                delay = target - time.perf_counter()
                if delay > 0:
                    time.sleep(delay)
                try:
                    out = cli.call("decode", payload)
                    done = time.perf_counter()
                    lat = done - target
                    with lock:
                        ok.append(lat)
                        last_done[0] = max(last_done[0], done)
                        if killed_at[0] is not None and target > killed_at[0]:
                            post_kill_ok.append(lat)
                        if bytes(np.asarray(out)[:PAYLOAD_BYTES]) \
                                != bytes(payload):
                            wrong[0] += 1
                except (ServiceCrashed, ServiceUnavailable,
                        ResponseTimeout) as e:
                    with lock:
                        typed.append(type(e).__name__)
                except Exception as e:  # pragma: no cover - gate trips
                    with lock:
                        lost.append(f"{type(e).__name__}: {e}")
        finally:
            cli.close()

    killed_pids: List[int] = []

    def _kill_one_active() -> bool:
        """SIGKILL the lowest-rid ACTIVE replica with a live child (the
        victim set changes under a supervisor). → True if one died."""
        for rep in fleet._replicas.values():
            proc = rep.session._proc if rep.state == REPLICA_ACTIVE \
                else None
            if proc is not None and proc.pid not in killed_pids:
                os.kill(proc.pid, signal.SIGKILL)
                killed_pids.append(proc.pid)
                with lock:
                    if killed_at[0] is None:
                        killed_at[0] = time.perf_counter()
                return True
        return False

    capacity_active = None
    try:
        # serial warmup: every client opens its channel and every replica
        # child forks off the clock (also gives the kill cell its victim)
        warm = gw.connect("warm")
        warm_calls = 3 * replicas
        for _ in range(warm_calls):
            warm.call("decode", payload)
        warm.close()
        if sup is not None:
            sup.start()
        clis = list(range(clients))
        gc.collect()
        gc.disable()
        try:
            t0 = time.perf_counter() + 0.05
            threads = [threading.Thread(target=worker, args=(i, t0),
                                        daemon=True) for i in clis]
            for t in threads:
                t.start()
            barrier.wait()
            if kill_rid is not None:
                t_mid = t0 + float(schedule[n // 2])
                time.sleep(max(0.0, t_mid - time.perf_counter()))
                proc = fleet._replicas[kill_rid].session._proc
                os.kill(proc.pid, signal.SIGKILL)
                with lock:
                    killed_at[0] = time.perf_counter()
            for frac in sorted(kill_at or ()):
                t_kill = t0 + float(schedule[min(n - 1, int(frac * n))])
                time.sleep(max(0.0, t_kill - time.perf_counter()))
                _kill_one_active()
            for t in threads:
                t.join()
        finally:
            gc.enable()
        if sup is not None:
            # capacity must converge back to target within the window
            heal_deadline = time.perf_counter() + HEAL_WINDOW_S
            while time.perf_counter() < heal_deadline:
                capacity_active = sum(
                    1 for r in fleet.snapshot() if r["state"] == "active")
                if (capacity_active == supervise
                        and sup.stats["respawns"] >= len(killed_pids)):
                    break
                time.sleep(SUP_INTERVAL)
            sup.stop()
        snapshot = gw.fleet_stats()["decode"]
        stats = dict(fleet.stats)
    finally:
        if sup is not None:
            sup.stop()
        gw.close()

    span = max(1e-9, last_done[0] - t0)
    lat_a = np.sort(np.asarray(ok) if ok else np.zeros(1))
    pk = np.sort(np.asarray(post_kill_ok)) if post_kill_ok else None
    return {
        "replicas": replicas,
        "clients": clients,
        "profile": profile,
        "requests": n,
        "offered_rps": offered_rps,
        "service_ms": SERVICE_MS,
        "seconds": round(span, 4),
        "throughput_rps": round(len(ok) / span, 2),
        "p50_ms": round(float(np.percentile(lat_a, 50)) * 1e3, 3),
        "p99_ms": round(float(np.percentile(lat_a, 99)) * 1e3, 3),
        "completed": len(ok),
        "typed_errors": sorted(set(typed)),
        "typed_error_count": len(typed),
        "lost": lost,
        "wrong_answers": wrong[0],
        "killed_rid": kill_rid,
        "post_kill_p99_ms": (round(float(np.percentile(pk, 99)) * 1e3, 3)
                             if pk is not None else None),
        "kills": len(killed_pids) if kill_at else
                 (1 if kill_rid is not None else 0),
        "slow_rid": slow_rid,
        "warm_requests": warm_calls,
        "sum_served": sum(s["served"] for s in snapshot),
        "capacity_active": capacity_active,
        "supervisor": dict(sup.stats) if sup is not None else None,
        "hedge": ({"delay_s": hedge.get("delay"),
                   "hedges_fired": stats["hedges_fired"],
                   "hedges_won": stats["hedges_won"],
                   "budget_spent": budget.spent}
                  if hedge is not None else None),
        "fleet_stats": stats,
        "snapshot": snapshot,
    }


def fleet_ratio(cells: List[Dict], clients: int = GATE_CLIENTS):
    """4-replica / 1-replica Poisson throughput ratio at ``clients`` —
    the machine-independent number the perf gate re-measures."""
    def rps(replicas):
        for c in cells:
            if (c["replicas"] == replicas and c["clients"] == clients
                    and c["profile"] == "poisson"
                    and c.get("killed_rid") is None):
                return c["throughput_rps"]
        return None
    one, four = rps(1), rps(4)
    if not one or not four:
        return None
    return round(four / one, 3)


def _midscale_cell(clients: int, n: int) -> Dict:
    """Supervised 4-replica fleet, kill -9 at 50% AND 70% of the
    schedule — the second lands during the first's release/join scale
    event."""
    return run_cell(4, clients, n, "poisson", kill_at=MIDSCALE_KILL_AT,
                    supervise=4)


def _hedge_pair(clients: int, n: int):
    """Same seeded schedule, one SLOW_FACTOR-slower replica, hedging off
    vs on. Offered below capacity so p99 measures the slow-replica tail,
    not queueing collapse."""
    common = dict(slow_rid=0, offered_rps=HEDGE_OFFERED)
    unhedged = run_cell(4, clients, n, "poisson", **common)
    # a standalone fleet budget never earns (earning is the client retry
    # layer's side of a shared instance — protocol §9.3), so fund it for
    # the whole schedule: the gate measures hedging, not budget starvation
    hedged = run_cell(4, clients, n, "poisson", **common,
                      hedge={"delay": HEDGE_DELAY_S,
                             "budget": RetryBudget(ratio=1.0, burst=n,
                                                   initial=n)})
    return unhedged, hedged


def _executed_once(cell: Dict) -> bool:
    """Every completion executed on exactly one replica: the fleet-wide
    served count equals completions + warmup, nothing double-ran."""
    return (cell["sum_served"]
            == cell["completed"] + cell["warm_requests"]
            and cell["completed"] == cell["requests"])


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true",
                    help="gate cells only, fewer clients/requests")
    ap.add_argument("--out", default=None, help="write JSON here too")
    ap.add_argument("--clients", default=None,
                    help="comma-separated extra client counts for the "
                         "upper sweep (recorded, not gated)")
    args = ap.parse_args(argv)

    clients = 64 if args.quick else CLIENTS
    n = 320 if args.quick else TOTAL_REQUESTS
    profiles = ["poisson"] if args.quick else ["poisson", "bursty"]

    def show(c, label=""):
        extra = ""
        if c["killed_rid"] is not None:
            extra += (f" killed=r{c['killed_rid']} "
                      f"post-kill p99={c['post_kill_p99_ms']}ms")
        if c["supervisor"] is not None:
            extra += (f" kills={c['kills']} "
                      f"respawns={c['supervisor']['respawns']} "
                      f"active={c['capacity_active']}")
        if c["hedge"] is not None:
            extra += f" hedges={c['hedge']['hedges_fired']}"
        print(f"  {label or str(c['replicas']) + 'r'} "
              f"{c['profile']:<8} c={c['clients']:<4} "
              f"{c['throughput_rps']:>8} req/s p50={c['p50_ms']}ms "
              f"p99={c['p99_ms']}ms typed={c['typed_error_count']} "
              f"lost={len(c['lost'])} wrong={c['wrong_answers']}"
              + extra, flush=True)

    cells: List[Dict] = []
    for profile in profiles:
        for replicas in REPLICA_COUNTS:
            cell = run_cell(replicas, clients, n, profile)
            cells.append(cell)
            show(cell)

    # chaos cell: kill one of 4 replicas at the Poisson schedule midpoint
    kill_cell = run_cell(4, clients, n, "poisson", kill_rid=1)
    cells.append(kill_cell)
    show(kill_cell)

    # mid-scale-event chaos: supervised fleet, repeated kill -9; best of
    # up to GATE_ATTEMPTS tries vs the fault-free 4r Poisson cell above
    faultfree_rps = next(c["throughput_rps"] for c in cells
                         if c["replicas"] == 4 and c["profile"] == "poisson"
                         and c.get("killed_rid") is None)
    mid_cell = None
    mid_ratio = None
    for attempt in range(GATE_ATTEMPTS):
        cell = _midscale_cell(clients, n)
        r = round(cell["throughput_rps"] / faultfree_rps, 3)
        show(cell, label="mid")
        if mid_ratio is None or r > mid_ratio:
            mid_cell, mid_ratio = cell, r
        healthy = (not cell["lost"] and cell["capacity_active"] == 4
                   and cell["supervisor"]["respawns"] >= cell["kills"] >= 2)
        if healthy and r >= MIDSCALE_FLOOR:
            mid_cell, mid_ratio = cell, r
            break
    cells.append(mid_cell)

    # hedge pair: p99 must improve with the executed count unchanged
    unhedged = hedged = None
    for attempt in range(GATE_ATTEMPTS):
        unhedged, hedged = _hedge_pair(clients, n)
        show(unhedged, label="unhedged")
        show(hedged, label="hedged")
        if (hedged["p99_ms"] <= unhedged["p99_ms"]
                and hedged["hedge"]["hedges_fired"] > 0
                and _executed_once(hedged) and _executed_once(unhedged)):
            break
    cells.extend([unhedged, hedged])

    # ROADMAP upper sweep: extra client counts, recorded but not gated
    sweep_cells: List[Dict] = []
    if args.clients:
        for c in [int(x) for x in args.clients.split(",") if x.strip()]:
            cell = run_cell(4, c, max(n, 2 * c), "poisson")
            sweep_cells.append(cell)
            show(cell, label="sweep")

    # scaling gate: best paired 1r/4r attempt (see module docstring)
    attempts = [fleet_ratio(cells, clients)]
    while (len(attempts) < GATE_ATTEMPTS
           and not any(r is not None and r >= GATE_FLOOR for r in attempts)):
        pair = [run_cell(r, clients, n, "poisson") for r in (1, 4)]
        attempts.append(fleet_ratio(pair, clients))
        print(f"  gate retry {len(attempts) - 1}: 1r "
              f"{pair[0]['throughput_rps']} 4r {pair[1]['throughput_rps']} "
              f"ratio {attempts[-1]}", flush=True)
        cells.extend(dict(c, gate_retry=len(attempts) - 1) for c in pair)
    ratio = max((r for r in attempts if r is not None), default=None)

    kill_victim = [s for s in kill_cell["snapshot"] if s["rid"] == 1]
    gates = {
        "all_answers_correct": all(c["wrong_answers"] == 0 for c in cells),
        "no_lost_requests": all(not c["lost"] for c in cells),
        "kill_cell_zero_lost": (not kill_cell["lost"]
                                and kill_cell["completed"]
                                + kill_cell["typed_error_count"]
                                == kill_cell["requests"]),
        "kill_victim_marked_dead": bool(kill_victim
                                        and kill_victim[0]["state"]
                                        == "dead"),
        "gate_attempt_ratios": attempts,
        "fleet_4r_vs_1r_rps_ratio_poisson": ratio,
        "fleet_4r_2x_1r_poisson": ratio is not None and ratio >= GATE_FLOOR,
        # mid-scale-event chaos (supervised, repeated kill -9)
        "midscale_zero_lost": (not mid_cell["lost"]
                               and mid_cell["completed"]
                               + mid_cell["typed_error_count"]
                               == mid_cell["requests"]),
        "midscale_capacity_restored": mid_cell["capacity_active"] == 4,
        "midscale_respawns_cover_kills": (
            mid_cell["kills"] >= 2
            and mid_cell["supervisor"]["respawns"] >= mid_cell["kills"]),
        "midscale_rps_ratio_vs_faultfree": mid_ratio,
        "midscale_70pct_throughput": (mid_ratio is not None
                                      and mid_ratio >= MIDSCALE_FLOOR),
        # hedging (late binding: one wire send ever)
        "hedged_p99_ms": hedged["p99_ms"],
        "unhedged_p99_ms": unhedged["p99_ms"],
        "hedges_fired": hedged["hedge"]["hedges_fired"],
        "hedged_p99_le_unhedged": (hedged["p99_ms"] <= unhedged["p99_ms"]
                                   and hedged["hedge"]["hedges_fired"] > 0),
        "hedge_executed_count_unchanged": (_executed_once(hedged)
                                           and _executed_once(unhedged)),
    }
    report = {
        "meta": {"clients": clients, "requests": n, "profiles": profiles,
                 "replica_counts": list(REPLICA_COUNTS),
                 "offered_rps": OFFERED_RPS, "service_ms": SERVICE_MS,
                 "burst": BURST, "timeout_s": TIMEOUT,
                 "gate_floor": GATE_FLOOR, "gate_attempts": GATE_ATTEMPTS,
                 "midscale_kill_at": list(MIDSCALE_KILL_AT),
                 "midscale_floor": MIDSCALE_FLOOR,
                 "heal_window_s": HEAL_WINDOW_S,
                 "slow_factor": SLOW_FACTOR,
                 "hedge_delay_s": HEDGE_DELAY_S,
                 "hedge_offered_rps": HEDGE_OFFERED,
                 "sweep_clients": ([int(x) for x in
                                    args.clients.split(",") if x.strip()]
                                   if args.clients else []),
                 "quick": args.quick},
        "results": cells,
        "client_sweep": sweep_cells,
        "gates": gates,
    }
    blob = json.dumps(report, indent=2)
    print(blob)
    if args.out:
        os.makedirs(os.path.dirname(args.out) or ".", exist_ok=True)
        with open(args.out, "w") as f:
            f.write(blob)
    ok = (gates["all_answers_correct"] and gates["no_lost_requests"]
          and gates["kill_cell_zero_lost"] and gates["fleet_4r_2x_1r_poisson"]
          and gates["kill_victim_marked_dead"]
          and gates["midscale_zero_lost"]
          and gates["midscale_capacity_restored"]
          and gates["midscale_respawns_cover_kills"]
          and gates["midscale_70pct_throughput"]
          and gates["hedged_p99_le_unhedged"]
          and gates["hedge_executed_count_unchanged"])
    if not ok:
        print("FLEET GATES FAILED", flush=True)
    return 0 if ok else 1


if __name__ == "__main__":
    raise SystemExit(main())
